"""Pallas TPU kernel: min-hash shingle computation (§3.1).

The SHINGLE partitioner's dominant cost is computing, for every record, ``L``
min-hashes over the set of versions the record belongs to (millions of
records × dozens of hash lanes).  TPU adaptation: version lists are padded
into ``(R, D)`` int32 tiles (CSR rows padded with -1); the kernel streams
``(BLOCK_R, D)`` tiles through VMEM, evaluates the multiply-shift universal
hash ``h_l(v) = a_l · v + b_l  (mod 2^32)`` on the VPU for each lane, and
takes a masked row-min.  Output is laid out ``(L, R)`` so the record axis
rides the 128-wide lane dimension.

Working set per grid step: BLOCK_R·D·4 bytes (≤1 MiB for D ≤ 2048) — well
under VMEM.  BLOCK_R = 128 keeps both tile axes hardware-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_R = 128
PAD_VERSION = -1
_EMPTY_HASH = np.uint32(0xFFFFFFFF)


def _minhash_kernel(vers_ref, a_ref, b_ref, out_ref, *, n_hashes: int):
    v = vers_ref[...]                                  # (BLOCK_R, D) int32
    valid = v != PAD_VERSION
    vu = v.astype(jnp.uint32)
    for l in range(n_hashes):                          # static unroll over lanes
        a = a_ref[0, l]
        b = b_ref[0, l]
        hv = a * vu + b                                # uint32 wraparound hash
        hv = jnp.where(valid, hv, _EMPTY_HASH)
        out_ref[l, :] = jnp.min(hv, axis=1)


def minhash(versions_padded: jax.Array, a: jax.Array, b: jax.Array,
            *, interpret: bool = True) -> jax.Array:
    """Min-hash each padded row.

    Args:
      versions_padded: (R, D) int32, rows padded with -1.  R % 128 == 0,
        D % 128 == 0 (callers pad; see ops.minhash_csr).
      a, b: (L,) uint32 hash-family parameters (a odd).
    Returns:
      (L, R) uint32 min-hash values; empty rows yield 0xFFFFFFFF.
    """
    R, D = versions_padded.shape
    L = a.shape[0]
    if R % BLOCK_R:
        raise ValueError(f"R={R} must be a multiple of {BLOCK_R}")
    a2 = a.reshape(1, L)
    b2 = b.reshape(1, L)
    grid = (R // BLOCK_R,)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, n_hashes=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, D), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, BLOCK_R), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((L, R), jnp.uint32),
        interpret=interpret,
    )(versions_padded, a2, b2)
