"""Pallas TPU kernel: XOR-delta record encoding (§3.4 record-level compression).

Sub-chunk compression delta-encodes each record against its version-tree
parent.  For fixed-width payloads (the framework's checkpoint blocks and the
paper's equal-sized JSON records) the delta is a word-wise XOR — zero words
mark unchanged bytes, which downstream entropy coding (zlib on host) or
sparse encoding exploits.  The same kernel powers gradient/update compression
in ``train/grad_compress.py``.

Layout: payloads as (N, W) uint32 words.  Grid streams (BLOCK_N, W) tiles
through VMEM; outputs the XOR tile plus a per-record changed-word count laid
out (1, N) so the record axis rides the lane dimension.  Decode is the same
XOR (an involution), so one kernel serves both directions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _xor_delta_kernel(parent_ref, child_ref, delta_ref, count_ref):
    p = parent_ref[...]                    # (BLOCK_N, W) uint32
    c = child_ref[...]
    d = p ^ c
    delta_ref[...] = d
    count_ref[0, :] = jnp.sum((d != 0).astype(jnp.int32), axis=1)


def xor_delta(parent: jax.Array, child: jax.Array,
              *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """XOR-delta encode (or decode) fixed-width payloads.

    Args:
      parent, child: (N, W) uint32; N % 128 == 0 (callers pad).
    Returns:
      (delta (N, W) uint32, changed_words (N,) int32).
    """
    N, W = parent.shape
    if parent.shape != child.shape:
        raise ValueError("parent/child shape mismatch")
    if N % BLOCK_N:
        raise ValueError(f"N={N} must be a multiple of {BLOCK_N}")
    grid = (N // BLOCK_N,)
    delta, counts = pl.pallas_call(
        _xor_delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, N), jnp.int32),
        ],
        interpret=interpret,
    )(parent, child)
    return delta, counts[0]
