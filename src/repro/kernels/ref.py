"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function mirrors its kernel's contract exactly (same shapes, dtypes and
padding conventions) using only high-level jnp ops.  Kernel tests sweep
shapes/dtypes and assert bit-exact equality against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PAD_VERSION = -1
_EMPTY_HASH = np.uint32(0xFFFFFFFF)


def minhash_ref(versions_padded: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """(R, D) padded rows, (L,) hash params → (L, R) uint32 min-hashes."""
    valid = versions_padded != PAD_VERSION                      # (R, D)
    vu = versions_padded.astype(jnp.uint32)                     # (R, D)
    hv = a[:, None, None] * vu[None] + b[:, None, None]         # (L, R, D)
    hv = jnp.where(valid[None], hv, _EMPTY_HASH)
    return jnp.min(hv, axis=-1)                                 # (L, R)


def xor_delta_ref(parent: jax.Array, child: jax.Array) -> tuple[jax.Array, jax.Array]:
    delta = parent ^ child
    counts = jnp.sum((delta != 0).astype(jnp.int32), axis=1)
    return delta, counts


def popcount32_ref(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def and_popcount_ref(bitmaps: jax.Array, row: jax.Array) -> tuple[jax.Array, jax.Array]:
    anded = bitmaps & row
    counts = jnp.sum(popcount32_ref(anded).astype(jnp.int32), axis=1)
    return anded, counts


def bitmap_vm_ref(regs: jax.Array, prog: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(S, W) uint32 registers, (P, 4) int32 ``(op, dst, lhs, rhs)`` stream
    with op in {0: AND, 1: OR, 2: ANDNOT} → (final registers, per-row
    popcounts).  P == 0 passes the register file through unchanged."""

    def body(i, r):
        op = prog[i, 0]
        a = jax.lax.dynamic_index_in_dim(r, prog[i, 2], axis=0, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(r, prog[i, 3], axis=0, keepdims=False)
        val = jnp.where(op == 0, a & b, jnp.where(op == 1, a | b, a & ~b))
        return jax.lax.dynamic_update_index_in_dim(r, val, prog[i, 1], axis=0)

    out = jax.lax.fori_loop(0, prog.shape[0], body, regs)
    counts = jnp.sum(popcount32_ref(out).astype(jnp.int32), axis=1)
    return out, counts
