"""Pallas TPU kernels: bitmap AND + popcount, and the bitmap VM (§2.4).

Record/range retrieval intersects the two lossy projections (key→chunks and
version→chunks).  With chunk membership as bitmaps (1 bit per chunk), the
intersection is a bitwise AND and the candidate count a popcount.  The
``and_popcount`` kernel ANDs a batch of key bitmaps (N, W) against either one
shared version bitmap (1, W) held in VMEM across the whole grid (single-query
index-ANDing) or a per-row batch of version bitmaps (N, W) tiled with the
keys (the plan/execute engine's batched sessions: row i carries query i's
version bitmap), emitting the AND tiles plus per-row popcounts.

Composite predicates (``Q.and_``/``Q.or_``/``Q.not_`` trees planned by
``core/plan.py``) need more than one pairwise AND, so ``bitmap_vm`` runs a
small *bitmap program*: an (S, W) uint32 register file (leaf rows — OR'd
posting lists and version bitmaps — followed by zeroed instruction outputs)
and a (P, 4) int32 instruction stream ``(opcode, dst, lhs, rhs)`` with
opcodes AND / OR / ANDNOT.  Instructions execute in order (``regs[dst] =
op(regs[lhs], regs[rhs])``), so an arbitrary predicate tree over projection
and secondary-index bitmaps evaluates in ONE fused launch; the final
register file and per-row popcounts come back together.  An empty program
passes the register file through unchanged.  The instruction stream lives in
SMEM (scalar memory) — its fields drive dynamic row indexing into the VMEM
register file.

Popcount uses the SWAR bit-twiddle (no LUT: TPU VPU has no gather), entirely
in uint32 lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 128

# bitmap-VM opcodes (prog[:, 0])
OP_AND = 0
OP_OR = 1
OP_ANDNOT = 2


def _popcount32(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def _and_popcount_kernel(bms_ref, row_ref, out_ref, cnt_ref):
    # (BLOCK_N, W) & (1, W) broadcasts; & (BLOCK_N, W) is elementwise
    x = bms_ref[...] & row_ref[...]
    out_ref[...] = x
    cnt_ref[0, :] = jnp.sum(_popcount32(x).astype(jnp.int32), axis=1)


def and_popcount(bitmaps: jax.Array, row: jax.Array,
                 *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """AND a batch of bitmaps against one shared row or per-row bitmaps.

    Args:
      bitmaps: (N, W) uint32, N % 128 == 0.
      row: (1, W) uint32 (broadcast against every row) or (N, W) uint32
        (pairwise: row i ANDs bitmaps[i] — the batched-session plan path).
    Returns:
      (anded (N, W) uint32, popcounts (N,) int32).
    """
    N, W = bitmaps.shape
    if row.shape not in ((1, W), (N, W)):
        raise ValueError(f"row must be (1, {W}) or ({N}, {W}), got {row.shape}")
    pairwise = row.shape[0] == N and N != 1
    if N % BLOCK_N:
        raise ValueError(f"N={N} must be a multiple of {BLOCK_N}")
    grid = (N // BLOCK_N,)
    row_spec = (pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)) if pairwise
                else pl.BlockSpec((1, W), lambda i: (0, 0)))
    anded, counts = pl.pallas_call(
        _and_popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            row_spec,
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, N), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, row)
    return anded, counts[0]


# ------------------------------------------------------------------ bitmap VM
def _bitmap_vm_kernel(prog_ref, regs_ref, out_ref, cnt_ref):
    # copy the register file, then execute the program in place: every
    # instruction reads/writes whole (1, W) rows at dynamic (SMEM-sourced)
    # sublane offsets
    out_ref[...] = regs_ref[...]

    def body(i, carry):
        op = prog_ref[i, 0]
        dst = prog_ref[i, 1]
        lhs = prog_ref[i, 2]
        rhs = prog_ref[i, 3]
        a = pl.load(out_ref, (pl.ds(lhs, 1), slice(None)))
        b = pl.load(out_ref, (pl.ds(rhs, 1), slice(None)))
        r = jnp.where(op == OP_AND, a & b,
                      jnp.where(op == OP_OR, a | b, a & ~b))
        pl.store(out_ref, (pl.ds(dst, 1), slice(None)), r)
        return carry

    jax.lax.fori_loop(0, prog_ref.shape[0], body, 0)
    cnt_ref[0, :] = jnp.sum(_popcount32(out_ref[...]).astype(jnp.int32), axis=1)


def bitmap_vm(regs: jax.Array, prog: jax.Array,
              *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Execute a bitmap program over an (S, W) uint32 register file.

    Args:
      regs: (S, W) uint32 register file (leaf bitmaps + zeroed scratch rows).
      prog: (P, 4) int32 instructions ``(opcode, dst, lhs, rhs)`` with
        opcode in {OP_AND, OP_OR, OP_ANDNOT} and row operands in [0, S).
        P == 0 is the empty program (register file passes through).
    Returns:
      (final registers (S, W) uint32, per-row popcounts (S,) int32).
    """
    S, W = regs.shape
    P = prog.shape[0]
    if prog.ndim != 2 or prog.shape[1] != 4:
        raise ValueError(f"prog must be (P, 4) int32, got {prog.shape}")
    if P == 0:
        # nothing to execute — popcount-only; keeps the kernel's loop bounds
        # static and the empty-program contract explicit
        counts = jnp.sum(_popcount32(regs).astype(jnp.int32), axis=1)
        return regs, counts
    out, counts = pl.pallas_call(
        _bitmap_vm_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((P, 4), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((S, W), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((S, W), lambda i: (0, 0)),
            pl.BlockSpec((1, S), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, S), jnp.int32),
        ],
        interpret=interpret,
    )(prog, regs)
    return out, counts[0]
