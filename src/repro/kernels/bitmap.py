"""Pallas TPU kernel: bitmap AND + popcount for index-ANDing (§2.4).

Record/range retrieval intersects the two lossy projections (key→chunks and
version→chunks).  With chunk membership as bitmaps (1 bit per chunk), the
intersection is a bitwise AND and the candidate count a popcount.  The kernel
ANDs a batch of key bitmaps (N, W) against either one shared version bitmap
(1, W) held in VMEM across the whole grid (single-query index-ANDing) or a
per-row batch of version bitmaps (N, W) tiled with the keys (the plan/execute
engine's batched sessions: row i carries query i's version bitmap), emitting
the AND tiles plus per-row popcounts.

Popcount uses the SWAR bit-twiddle (no LUT: TPU VPU has no gather), entirely
in uint32 lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_N = 128


def _popcount32(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def _and_popcount_kernel(bms_ref, row_ref, out_ref, cnt_ref):
    # (BLOCK_N, W) & (1, W) broadcasts; & (BLOCK_N, W) is elementwise
    x = bms_ref[...] & row_ref[...]
    out_ref[...] = x
    cnt_ref[0, :] = jnp.sum(_popcount32(x).astype(jnp.int32), axis=1)


def and_popcount(bitmaps: jax.Array, row: jax.Array,
                 *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """AND a batch of bitmaps against one shared row or per-row bitmaps.

    Args:
      bitmaps: (N, W) uint32, N % 128 == 0.
      row: (1, W) uint32 (broadcast against every row) or (N, W) uint32
        (pairwise: row i ANDs bitmaps[i] — the batched-session plan path).
    Returns:
      (anded (N, W) uint32, popcounts (N,) int32).
    """
    N, W = bitmaps.shape
    if row.shape not in ((1, W), (N, W)):
        raise ValueError(f"row must be (1, {W}) or ({N}, {W}), got {row.shape}")
    pairwise = row.shape[0] == N and N != 1
    if N % BLOCK_N:
        raise ValueError(f"N={N} must be a multiple of {BLOCK_N}")
    grid = (N // BLOCK_N,)
    row_spec = (pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)) if pairwise
                else pl.BlockSpec((1, W), lambda i: (0, 0)))
    anded, counts = pl.pallas_call(
        _and_popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            row_spec,
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, N), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, row)
    return anded, counts[0]
