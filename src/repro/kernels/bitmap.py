"""Pallas TPU kernel: bitmap AND + popcount for index-ANDing (§2.4).

Record/range retrieval intersects the two lossy projections (key→chunks and
version→chunks).  With chunk membership as bitmaps (1 bit per chunk), the
intersection is a bitwise AND and the candidate count a popcount.  The kernel
ANDs a batch of key bitmaps (N, W) against one version bitmap (1, W) held in
VMEM across the whole grid, emitting the AND tiles plus per-row popcounts.

Popcount uses the SWAR bit-twiddle (no LUT: TPU VPU has no gather), entirely
in uint32 lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_N = 128


def _popcount32(v: jax.Array) -> jax.Array:
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def _and_popcount_kernel(bms_ref, row_ref, out_ref, cnt_ref):
    x = bms_ref[...] & row_ref[...]            # (BLOCK_N, W) & (1, W) broadcast
    out_ref[...] = x
    cnt_ref[0, :] = jnp.sum(_popcount32(x).astype(jnp.int32), axis=1)


def and_popcount(bitmaps: jax.Array, row: jax.Array,
                 *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """AND a batch of bitmaps against one row bitmap, with popcounts.

    Args:
      bitmaps: (N, W) uint32, N % 128 == 0.
      row: (1, W) uint32 (broadcast against every row).
    Returns:
      (anded (N, W) uint32, popcounts (N,) int32).
    """
    N, W = bitmaps.shape
    if row.shape != (1, W):
        raise ValueError(f"row must be (1, {W}), got {row.shape}")
    if N % BLOCK_N:
        raise ValueError(f"N={N} must be a multiple of {BLOCK_N}")
    grid = (N // BLOCK_N,)
    anded, counts = pl.pallas_call(
        _and_popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, W), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, W), jnp.uint32),
            jax.ShapeDtypeStruct((1, N), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, row)
    return anded, counts[0]
