"""Public jit'd wrappers around the Pallas kernels.

These handle padding/blocking to the kernels' tile contracts and expose
NumPy-friendly entry points for the host-side partitioners.

Dispatch policy: on TPU the Pallas kernels run compiled; on CPU (this
container) the *batch* entry points route through the jitted jnp oracles
(bit-identical — asserted by tests/test_kernels.py, which also exercises the
kernels under interpret=True), because interpret-mode Pallas executes kernel
bodies in Python and is orders of magnitude too slow for the multi-million-
record benchmark workloads.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitmap as _bitmap
from . import deltaenc as _deltaenc
from . import minhash as _minhash

INTERPRET = jax.default_backend() != "tpu"

_P_LANE = 128


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ------------------------------------------------------------------ minhash
@functools.partial(jax.jit, static_argnames=("interpret",))
def _minhash_jit(vers, a, b, interpret=INTERPRET):
    return _minhash.minhash(vers, a, b, interpret=interpret)


def hash_family(n_hashes: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Multiply-shift universal hash family: odd multipliers + offsets."""
    rng = np.random.default_rng(seed)
    a = (rng.integers(0, 2**32, size=n_hashes, dtype=np.uint32) | 1).astype(np.uint32)
    b = rng.integers(0, 2**32, size=n_hashes, dtype=np.uint32)
    return a, b


def minhash_padded(versions_padded: np.ndarray, a: np.ndarray, b: np.ndarray,
                   *, interpret: bool = INTERPRET) -> np.ndarray:
    """Pad (R, D) rows to tile boundaries and run the kernel. Returns (R, L)."""
    R, D = versions_padded.shape
    Rp = _pad_to(max(R, 1), _minhash.BLOCK_R)
    Dp = _pad_to(max(D, 1), _P_LANE)
    buf = np.full((Rp, Dp), _minhash.PAD_VERSION, dtype=np.int32)
    buf[:R, :D] = versions_padded
    out = _minhash_jit(jnp.asarray(buf), jnp.asarray(a), jnp.asarray(b),
                       interpret=interpret)
    return np.asarray(out)[:, :R].T  # (R, L)


@functools.partial(jax.jit, static_argnames=())
def _minhash_ref_jit(vers, a, b):
    from . import ref
    return ref.minhash_ref(vers, a, b)


def minhash_csr(indptr: np.ndarray, col: np.ndarray, a: np.ndarray, b: np.ndarray,
                *, block_rows: int = 8192, interpret: bool = INTERPRET,
                force_kernel: bool = False) -> np.ndarray:
    """Min-hash ragged CSR rows.

    Rows are processed in blocks; each block is padded to its own max degree
    (rounded to the 128-lane boundary and bucketed to powers of two to bound
    recompiles).  Returns (R, L) uint32; empty rows → 0xFFFFFFFF.
    """
    R = len(indptr) - 1
    L = len(a)
    out = np.empty((R, L), dtype=np.uint32)
    for lo in range(0, R, block_rows):
        hi = min(lo + block_rows, R)
        ptr = indptr[lo:hi + 1]
        deg = np.diff(ptr)
        dmax = int(deg.max()) if len(deg) else 0
        Dp = _P_LANE
        while Dp < dmax:
            Dp *= 2
        block = np.full((hi - lo, Dp), _minhash.PAD_VERSION, dtype=np.int32)
        # scatter CSR rows into the padded block
        rows = np.repeat(np.arange(hi - lo), deg)
        offs = np.arange(ptr[-1] - ptr[0]) - np.repeat(ptr[:-1] - ptr[0], deg)
        block[rows, offs] = col[ptr[0]:ptr[-1]]
        if interpret and not force_kernel:
            # interpret-mode pallas executes the kernel body in Python —
            # far too slow for multi-million-record host workloads.  Use the
            # jitted jnp oracle (bit-identical; asserted by the kernel tests)
            # and reserve the kernel for real-TPU runs / explicit validation.
            got = np.asarray(_minhash_ref_jit(
                jnp.asarray(block), jnp.asarray(a), jnp.asarray(b))).T
        else:
            got = minhash_padded(block, a, b, interpret=interpret)
        out[lo:hi] = got
    return out


# ---------------------------------------------------------------- xor delta
@functools.partial(jax.jit, static_argnames=("interpret",))
def _xor_jit(p, c, interpret=INTERPRET):
    return _deltaenc.xor_delta(p, c, interpret=interpret)


@jax.jit
def _xor_ref_jit(p, c):
    from . import ref
    return ref.xor_delta_ref(p, c)


def _bytes_to_words(buf: bytes, width: int) -> np.ndarray:
    arr = np.frombuffer(buf, dtype=np.uint8)
    pad = _pad_to(max(width, 4), 4)
    out = np.zeros(pad, dtype=np.uint8)
    out[:len(arr)] = arr
    return out.view(np.uint32)


def xor_delta_batch(parent: np.ndarray, child: np.ndarray,
                    *, interpret: bool = INTERPRET) -> Tuple[np.ndarray, np.ndarray]:
    """(N, W) uint32 batches → (delta (N, W), changed_words (N,)). Pads N."""
    N, W = parent.shape
    Np = _pad_to(max(N, 1), _deltaenc.BLOCK_N)
    Wp = _pad_to(max(W, 1), _P_LANE)
    pb = np.zeros((Np, Wp), dtype=np.uint32)
    cb = np.zeros((Np, Wp), dtype=np.uint32)
    pb[:N, :W] = parent
    cb[:N, :W] = child
    if interpret:
        d, cnt = _xor_ref_jit(jnp.asarray(pb), jnp.asarray(cb))
    else:
        d, cnt = _xor_jit(jnp.asarray(pb), jnp.asarray(cb), interpret=False)
    return np.asarray(d)[:N, :W], np.asarray(cnt)[:N]


def xor_delta_bytes(parent: bytes, child: bytes,
                    *, interpret: bool = INTERPRET) -> Tuple[bytes, int]:
    """Delta-encode one payload against its parent (decode is the same call)."""
    w = max(len(parent), len(child))
    pw = _bytes_to_words(parent, w)
    cw = _bytes_to_words(child, w)
    d, cnt = xor_delta_batch(pw[None, :], cw[None, :], interpret=interpret)
    return d[0].tobytes()[:w], int(cnt[0])


# ------------------------------------------------------------------- bitmap
# Fused bitmap-plan launches since import ("and_popcount family": the
# pairwise AND kernel and the bitmap VM).  The planner's one-launch-per-batch
# contract is asserted against deltas of this counter.
BITMAP_LAUNCHES = 0


@functools.partial(jax.jit, static_argnames=("interpret",))
def _and_jit(bms, row, interpret=INTERPRET):
    return _bitmap.and_popcount(bms, row, interpret=interpret)


def and_popcount_batch(bitmaps: np.ndarray, row: np.ndarray,
                       *, interpret: bool = INTERPRET) -> Tuple[np.ndarray, np.ndarray]:
    """AND (N, W) bitmaps against a row; returns (anded, popcounts).

    ``row`` is a single shared (W,)/(1, W) bitmap (broadcast against every
    bitmap — the single-query index-AND) or a pairwise (N, W) batch (row i
    ANDs bitmaps[i] — one kernel launch plans a whole query session).
    """
    global BITMAP_LAUNCHES
    BITMAP_LAUNCHES += 1
    N, W = bitmaps.shape
    row = np.asarray(row)
    if row.ndim == 1:
        row = row[None, :]
    if row.shape not in ((1, W), (N, W)):
        raise ValueError(f"row must be ({W},), (1, {W}) or ({N}, {W}); "
                         f"got {row.shape}")
    pairwise = row.shape[0] == N and N != 1
    Np = _pad_to(max(N, 1), _bitmap.BLOCK_N)
    Wp = _pad_to(max(W, 1), _P_LANE)
    bb = np.zeros((Np, Wp), dtype=np.uint32)
    rb = np.zeros((Np if pairwise else 1, Wp), dtype=np.uint32)
    bb[:N, :W] = bitmaps
    rb[:row.shape[0], :W] = row
    if interpret:
        anded, cnt = _and_ref_jit(jnp.asarray(bb), jnp.asarray(rb))
    else:
        anded, cnt = _and_jit(jnp.asarray(bb), jnp.asarray(rb), interpret=False)
    return np.asarray(anded)[:N, :W], np.asarray(cnt)[:N]


@jax.jit
def _and_ref_jit(bms, row):
    from . import ref
    return ref.and_popcount_ref(bms, row)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _vm_jit(regs, prog, interpret=INTERPRET):
    return _bitmap.bitmap_vm(regs, prog, interpret=interpret)


@jax.jit
def _vm_ref_jit(regs, prog):
    from . import ref
    return ref.bitmap_vm_ref(regs, prog)


def bitmap_vm_batch(regs: np.ndarray, prog: np.ndarray,
                    *, interpret: bool = INTERPRET) -> Tuple[np.ndarray, np.ndarray]:
    """Run one bitmap program over an (S, W) uint32 register file.

    ``prog`` is (P, 4) int32 ``(opcode, dst, lhs, rhs)`` rows (opcodes
    ``bitmap.OP_AND`` / ``OP_OR`` / ``OP_ANDNOT``); an empty program is
    legal and passes the registers through.  Pads S and W to the lane
    boundary and P to a multiple of 8 with OR-identity no-ops (``regs[0] =
    regs[0] | regs[0]``) to bound jit recompiles, then returns the final
    registers ``(S, W)`` and per-row popcounts ``(S,)`` unpadded.  One call
    = one fused launch, whatever the predicate-tree shape.
    """
    global BITMAP_LAUNCHES
    BITMAP_LAUNCHES += 1
    S, W = regs.shape
    prog = np.asarray(prog, dtype=np.int32).reshape(-1, 4)
    if len(prog) and (prog[:, 1:].min() < 0 or prog[:, 1:].max() >= S):
        raise ValueError(f"program row operand out of range [0, {S})")
    Sp = _pad_to(max(S, 1), _P_LANE)   # popcount output lane dim
    Wp = _pad_to(max(W, 1), _P_LANE)
    Pp = _pad_to(max(len(prog), 1), 8)
    rb = np.zeros((Sp, Wp), dtype=np.uint32)
    rb[:S, :W] = regs
    pg = np.zeros((Pp, 4), dtype=np.int32)
    pg[:, 0] = _bitmap.OP_OR           # no-op padding: regs[0] |= regs[0]
    pg[:len(prog)] = prog
    if interpret:
        out, cnt = _vm_ref_jit(jnp.asarray(rb), jnp.asarray(pg))
    else:
        out, cnt = _vm_jit(jnp.asarray(rb), jnp.asarray(pg), interpret=False)
    return np.asarray(out)[:S, :W], np.asarray(cnt)[:S]
