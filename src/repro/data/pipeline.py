"""Deterministic synthetic data pipeline.

Tokens are a pure function of (step, position) via a counter-mode hash, so
the pipeline is stateless, skip-ahead (restart at step k never replays), and
identical across hosts — the properties a multi-pod fault-tolerant loader
needs.  A real deployment swaps `synthetic_batch` for a sharded file reader
with the same step→batch contract; everything downstream (train loop,
checkpoint manager, elastic restart) only sees the contract.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


def _hash2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Cheap counter-mode integer hash (xorshift-mult)."""
    x = (a.astype(jnp.uint32) * np.uint32(0x9E3779B9)) ^ \
        (b.astype(jnp.uint32) * np.uint32(0x85EBCA6B))
    x = x ^ (x >> 13)
    x = x * np.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def synthetic_batch(cfg: ModelConfig, step: int, batch: int, seq: int,
                    as_numpy: bool = False) -> Dict[str, jnp.ndarray]:
    """Batch for ``step``: tokens plus any modality-stub inputs."""
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None] + np.uint32(step * batch)
    cols = jnp.arange(seq, dtype=jnp.uint32)[None, :]
    toks = (_hash2(rows, cols) % np.uint32(cfg.vocab_size)).astype(jnp.int32)
    out: Dict[str, jnp.ndarray] = {"tokens": toks}
    if cfg.family == "vlm":
        P = cfg.n_prefix_embeds
        pe = _hash2(rows[:, :, None] * 0 + rows[:, :, None],
                    (jnp.arange(P * cfg.d_model, dtype=jnp.uint32)
                     .reshape(1, P, cfg.d_model)))
        out["prefix_embeds"] = (pe.astype(jnp.float32) / np.float32(2**32) - 0.5)
    if cfg.family == "encdec":
        fr = _hash2(rows[:, :, None],
                    jnp.arange(seq * cfg.d_model, dtype=jnp.uint32)
                    .reshape(1, seq, cfg.d_model) % np.uint32(2**31))
        out["frames"] = (fr.astype(jnp.float32) / np.float32(2**32) - 0.5)
    if as_numpy:
        out = {k: np.asarray(v) for k, v in out.items()}
    return out


def batch_spec(cfg: ModelConfig, batch: int, seq: int, env=None):
    """ShapeDtypeStructs (with shardings) for one batch — dry-run inputs."""
    def sds(shape, axes, dtype):
        sh = env.sharding_for(shape, axes) if env else None
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

    out = {"tokens": sds((batch, seq), ("batch", None), jnp.int32)}
    if cfg.family == "vlm":
        out["prefix_embeds"] = sds((batch, cfg.n_prefix_embeds, cfg.d_model),
                                   ("batch", None, None), jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = sds((batch, seq, cfg.d_model),
                            ("batch", None, None), jnp.float32)
    return out
