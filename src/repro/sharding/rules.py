"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Every parameter/activation dimension carries a *logical* axis name; rules map
it to zero or more mesh axes.  ``spec_for`` drops any assignment that does not
divide the dimension evenly (e.g. 15 attention heads over a 16-way model
axis), falling back to replication for that dim — this keeps one rule set
valid across all 10 architectures.

A context-managed ``MeshEnv`` carries (mesh, rules) so model code can request
activation sharding constraints without threading mesh plumbing everywhere;
outside any env (unit tests, single device) constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axes = Tuple[Optional[str], ...]

# Default logical→mesh rules.  "fsdp" axes shard weight rows (ZeRO-3 style);
# "tp" shards heads/hidden/vocab/experts; "dp" shards batch.  The pod axis
# folds into both dp and fsdp when present.
def default_rules(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    names = mesh.axis_names
    dp: Tuple[str, ...] = tuple(n for n in ("pod", "data") if n in names)
    tp: Tuple[str, ...] = ("model",) if "model" in names else ()
    return {
        "batch": dp,
        "fsdp": dp,
        "embed": dp,            # weight reduction dims → FSDP
        "vocab": tp,
        "heads": tp,
        "kv_heads": tp,
        "head_dim": (),
        "mlp": tp,
        "experts": tp,
        "expert_mlp": (),
        "ssm_heads": tp,
        "ssm_proj": tp,
        "layers": (),
        "seq": (),
        "cache_seq": tp,        # decode: shard KV cache sequence (flash-decode)
        "state": (),
        "conv": (),
        "capacity": dp,         # MoE dispatch buffer capacity dim
        "act_embed": (),        # activation hidden dim (replicated, 1D TP)
        "act_heads": tp,        # activation head dim
        "attn_batch": dp + tp,  # attention batch resharded over all axes
        "seq_sp": tp,           # sequence-parallel residual stream
    }


@dataclass
class MeshEnv:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]

    def spec_for(self, shape: Sequence[int], axes: Axes) -> P:
        assert len(shape) == len(axes), (shape, axes)
        used: set = set()
        parts = []
        for dim, ax in zip(shape, axes):
            if ax is None:
                parts.append(None)
                continue
            mesh_axes = tuple(a for a in self.rules.get(ax, ())
                              if a in self.mesh.axis_names and a not in used)
            size = math.prod(self.mesh.shape[a] for a in mesh_axes) if mesh_axes else 1
            if mesh_axes and size > 0 and dim % size == 0:
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
                used.update(mesh_axes)
            else:
                parts.append(None)     # indivisible → replicate this dim
        return P(*parts)

    def sharding_for(self, shape: Sequence[int], axes: Axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


def dp_only_rules(mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    """Pure data-parallel profile: batch over every mesh axis, FSDP weights
    over the data axes, no tensor parallelism.  The right regime for models
    whose per-layer shards would be tiny or whose head counts don't divide
    the TP width (e.g. smollm-360m) — avoids all activation resharding."""
    names = mesh.axis_names
    all_axes = tuple(n for n in ("pod", "data", "model") if n in names)
    dp = tuple(n for n in ("pod", "data") if n in names)
    base = default_rules(mesh)
    base.update({
        "batch": all_axes,
        "fsdp": dp,
        "embed": dp,
        "vocab": (), "heads": (), "kv_heads": (), "mlp": (),
        "experts": (), "ssm_heads": (), "ssm_proj": (),
        "act_heads": (), "attn_batch": all_axes,
        "capacity": all_axes,
    })
    return base


def rules_for(cfg, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    profile = getattr(cfg, "sharding_profile", "default")
    if profile == "dp_only":
        return dp_only_rules(mesh)
    return default_rules(mesh)


_CURRENT: list = []


@contextlib.contextmanager
def mesh_env(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    env = MeshEnv(mesh=mesh, rules={**default_rules(mesh), **(rules or {})})
    _CURRENT.append(env)
    try:
        with mesh:
            yield env
    finally:
        _CURRENT.pop()


def current_env() -> Optional[MeshEnv]:
    return _CURRENT[-1] if _CURRENT else None


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Activation sharding constraint by logical axes; no-op without a mesh."""
    env = current_env()
    if env is None:
        return x
    spec = env.spec_for(x.shape, tuple(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(env.mesh, spec))
