"""Serve-side ingest frontend: many named clients, one store, one flusher.

The serving tier terminates many concurrent client connections; giving
each its own :class:`~repro.core.ingest.RStore` (or serializing them
through the one-writer sync path) wastes exactly the batching the
:class:`~repro.core.flusher.BackgroundFlusher` exists to exploit.
:class:`IngestGateway` multiplexes named clients onto ONE store with a
flusher attached: every client's ``commit()`` stages at zero backend
round trips, and all clients' versions drain together in one group
commit per watermark (≤S write round trips on S shards, however many
clients are connected).

The gateway is deliberately thin — sessions are plain
:class:`~repro.core.ingest.WriteSession` objects in async mode; the
gateway adds per-client bookkeeping (staged counts for fair-share
accounting, mirroring the per-tenant direction in ROADMAP) and the
request-level entry points a server loop would expose: ``commit`` /
``barrier`` / ``snapshot`` / ``report``.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

from ..core.ingest import RStore, WriteSession


class IngestGateway:
    """Multiplex named clients onto one RStore + BackgroundFlusher.

    ``flusher_kw`` is forwarded to :meth:`RStore.attach_flusher` unless
    the store already has a flusher (then it must be empty — the gateway
    adopts the existing one rather than silently ignoring conflicting
    watermarks)."""

    def __init__(self, rs: RStore, **flusher_kw) -> None:
        self.rs = rs
        if rs.flusher is not None:
            if flusher_kw:
                raise ValueError(
                    "store already has a BackgroundFlusher attached; "
                    "gateway flusher kwargs would be ignored")
            self.flusher = rs.flusher
        else:
            self.flusher = rs.attach_flusher(**flusher_kw)
        self._sessions: Dict[str, WriteSession] = {}
        self._staged_by_client: Dict[str, int] = {}

    # ------------------------------------------------------------- sessions
    def open_session(self, client: str) -> WriteSession:
        """Open (or return) ``client``'s write session."""
        ws = self._sessions.get(client)
        if ws is None or ws._closed:
            ws = self.rs.writer()
            self._sessions[client] = ws
            self._staged_by_client.setdefault(client, 0)
        return ws

    def close_session(self, client: str) -> None:
        """Close ``client``'s session (no drain — watermarks own that).
        Unknown/already-closed clients are a no-op."""
        ws = self._sessions.pop(client, None)
        if ws is not None:
            ws.close()

    @property
    def open_clients(self) -> Sequence[str]:
        return sorted(c for c, ws in self._sessions.items()
                      if not ws._closed)

    # --------------------------------------------------------------- ingest
    def init_root(self, client: str, records: Dict[int, bytes]) -> int:
        vid = self.open_session(client).init_root(records)
        self._staged_by_client[client] += 1
        return vid

    def commit(self, client: str, parents: Sequence[int],
               adds: Dict[int, bytes], dels: Iterable[int] = ()) -> int:
        """Stage one commit for ``client`` — zero backend round trips;
        durability comes from the shared flusher's watermarks or
        :meth:`barrier`."""
        vid = self.open_session(client).commit(parents, adds, dels)
        self._staged_by_client[client] += 1
        return vid

    def barrier(self):
        """Drain on behalf of every client (one group commit)."""
        return self.rs.barrier()

    # ---------------------------------------------------------------- reads
    def snapshot(self, mode: str = "fresh"):
        return self.rs.snapshot(mode=mode)

    # ------------------------------------------------------------ reporting
    def report(self) -> Dict[str, object]:
        """Per-client staged totals plus the store's ingest sub-report."""
        return {
            "clients": dict(self._staged_by_client),
            "open_sessions": len(self.open_clients),
            "ingest": self.rs.storage_stats()["ingest"],
        }

    # ------------------------------------------------------------- shutdown
    def close(self) -> None:
        """Close every session and the flusher (final drain), returning
        the store to synchronous ingest.  Idempotent."""
        for client in list(self._sessions):
            self.close_session(client)
        self.flusher.close()
