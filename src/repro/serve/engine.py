"""Serving engines: the store's query front-end and the LLM decode loop.

:class:`StoreQueryEngine` is the RStore serving surface: it pins a snapshot
per wave of queries and routes every wave through the unified planner
(:mod:`repro.core.plan` via ``Snapshot.execute`` — the same one-launch /
one-multiget pipeline the session API uses), transparently re-pinning when
a compaction pass re-partitions chunk storage under it.

:class:`Engine` is the batched LLM engine: prefill + jitted greedy decode.
The decode loop runs as a single jitted ``lax.scan`` over steps (one dispatch
per generation call, not per token), with caches donated between steps — the
pattern a production server uses per wave of a continuous-batching scheduler.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model, build_model


class StoreQueryEngine:
    """Store-serving front-end: waves of queries over pinned snapshots.

    Holds one snapshot at a time and executes whole waves against it —
    planning, kernel launches and the KVS multiget are batched per wave by
    the planner, not per query.  A full ``build()`` under the engine
    invalidates the pin and the next wave re-snapshots; a compaction pass
    just re-pins via ``snapshot.refresh()``.
    """

    def __init__(self, rs) -> None:
        self.rs = rs
        self._snap = None
        self.waves_served = 0
        self.repins = 0

    def snapshot(self):
        """The current pinned snapshot (taken lazily, kept across waves)."""
        if self._snap is None:
            self._snap = self.rs.snapshot()
        return self._snap

    def _fresh_snapshot(self):
        snap = self.snapshot()
        try:
            snap._check_fresh()
        except RuntimeError:
            try:
                snap = snap.refresh()          # compaction: re-pin in place
            except RuntimeError:
                snap = self.rs.snapshot()      # full rebuild: new snapshot
            self._snap = snap
            self.repins += 1
        return snap

    def serve(self, queries: Sequence[Any]):
        """Execute one wave → :class:`~repro.core.plan.BatchResult`."""
        batch = self._fresh_snapshot().execute(list(queries))
        self.waves_served += 1
        return batch

    def explain(self, queries: Sequence[Any]) -> List[Dict[str, Any]]:
        """Rendered plans + predicted costs for a wave (no execution)."""
        return self._fresh_snapshot().explain(list(queries))

    def warm(self, queries: Sequence[Any]) -> Dict[str, int]:
        """Prefetch a wave's chunks into the cache layer, if one is on."""
        return self._fresh_snapshot().prefetch(list(queries))


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 4096):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=max_len))
        self._gen = jax.jit(self._generate_scan, static_argnames=("steps",))

    def _generate_scan(self, params, caches, first_tok, start_pos, *, steps):
        def step(carry, _):
            tok, pos, caches = carry
            nxt, caches = self.model.decode_step(params, caches, tok, pos)
            return (nxt[:, None], pos + 1, caches), nxt

        (_, _, caches), toks = jax.lax.scan(
            step, (first_tok, start_pos, caches), None, length=steps)
        return jnp.moveaxis(toks, 0, 1), caches     # (B, steps)

    def generate(self, batch: Dict[str, jax.Array], steps: int):
        """Greedy-decode ``steps`` tokens after the prompt."""
        prompt_len = batch["tokens"].shape[1]
        assert prompt_len + steps <= self.max_len, "exceeds cache capacity"
        logits, caches = self._prefill(self.params, batch)
        first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks, caches = self._gen(self.params, caches, first,
                                 jnp.int32(prompt_len), steps=steps - 1)
        return jnp.concatenate([first, toks], axis=1)
