"""Batched serving engine: prefill + jitted greedy decode loop.

The decode loop runs as a single jitted ``lax.scan`` over steps (one dispatch
per generation call, not per token), with caches donated between steps — the
pattern a production server uses per wave of a continuous-batching scheduler.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model, build_model


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 4096):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=max_len))
        self._gen = jax.jit(self._generate_scan, static_argnames=("steps",))

    def _generate_scan(self, params, caches, first_tok, start_pos, *, steps):
        def step(carry, _):
            tok, pos, caches = carry
            nxt, caches = self.model.decode_step(params, caches, tok, pos)
            return (nxt[:, None], pos + 1, caches), nxt

        (_, _, caches), toks = jax.lax.scan(
            step, (first_tok, start_pos, caches), None, length=steps)
        return jnp.moveaxis(toks, 0, 1), caches     # (B, steps)

    def generate(self, batch: Dict[str, jax.Array], steps: int):
        """Greedy-decode ``steps`` tokens after the prompt."""
        prompt_len = batch["tokens"].shape[1]
        assert prompt_len + steps <= self.max_len, "exceeds cache capacity"
        logits, caches = self._prefill(self.params, batch)
        first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks, caches = self._gen(self.params, caches, first,
                                 jnp.int32(prompt_len), steps=steps - 1)
        return jnp.concatenate([first, toks], axis=1)
