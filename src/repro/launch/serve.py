"""Serving driver: batched greedy generation with a versioned model registry.

  python -m repro.launch.serve --arch granite-moe-1b-a400m --reduced \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCHS
from ..data.pipeline import synthetic_batch
from ..models.model import build_model, init_params
from ..serve.engine import Engine


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m",
                    choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--waves", type=int, default=3,
                    help="batches served back-to-back (continuous batching)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "remat": "none"})
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=args.prompt_len + args.gen + 8)

    for wave in range(args.waves):
        batch = {"tokens": synthetic_batch(cfg, wave, args.batch,
                                           args.prompt_len)["tokens"]}
        t0 = time.time()
        toks = eng.generate(batch, steps=args.gen)
        dt = time.time() - t0
        print(f"wave {wave}: {toks.shape[0]}×{toks.shape[1]} tokens "
              f"in {dt:.2f}s ({toks.shape[0]*toks.shape[1]/dt:.1f} tok/s)"
              + (" [incl. compile]" if wave == 0 else ""))
    return 0


if __name__ == "__main__":
    run()
