"""Production mesh construction + mesh-aware KVS shard placement.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Single pod: 16×16 = 256 chips,
axes (data, model).  Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model);
the pod axis folds into data-parallel/FSDP sharding via the default rules.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.35: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types parameter
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Small mesh for local smoke runs (1 device by default)."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def make_sharded_backend(n_shards: int = 4, mesh: Mesh | None = None,
                         slot_bytes: int = 1 << 16, n_slots: int = 1024,
                         replication_factor: int = 1,
                         write_quorum: int | None = None,
                         retry=None,
                         cache_bytes: int | None = None,
                         cache_kw: dict | None = None):
    """Mesh-aware shard placement for the store backend.

    Returns a :class:`repro.core.kvs.ShardedKVS` router over ``n_shards``
    :class:`repro.core.kvs.ShardedDeviceKVS` tables.  With a mesh, each
    shard's slot table is pinned to its own round-robin slice of the mesh's
    devices (a strided 1-axis sub-mesh), so a group commit's per-shard
    ``multiput`` and a session read's per-shard ``multiget`` land on
    disjoint device sets.  With fewer devices than shards (CPU smoke runs)
    slices wrap; with no mesh each shard is still a device-table KVS, just
    placed on the default device (use ``ShardedKVS([InMemoryKVS()] * n)``
    for a host-only backend).

    With ``replication_factor=R > 1`` each shard becomes a
    :class:`repro.core.replica.ReplicatedKVS` group of R device tables, each
    replica on its own device slice (n_shards × R disjoint slices), so a
    replica death takes out one device group, not the shard: reads fail
    over inside the group, writes keep landing with ``write_quorum`` acks
    (default 1 — availability-first), and
    :class:`repro.core.replica.RecoveryManager` rebuilds lost replicas from
    the survivors.  ``retry`` is the group's
    :class:`repro.core.replica.RetryPolicy` (default policy if None).

    With ``cache_bytes`` set, the router is topped with a
    :class:`repro.core.cache.CachingKVS` chunk cache of that byte budget
    (``cache_kw`` passes through tuning knobs like ``always_admit_bytes``):
    hot chunks are then served at memory speed and a fully warm session
    ``multiget`` costs 0 device round trips.
    """
    from repro.core.cache import CachingKVS
    from repro.core.kvs import ShardedDeviceKVS, ShardedKVS
    from repro.core.replica import ReplicatedKVS

    def finish(router):
        if cache_bytes:
            return CachingKVS(router, cache_bytes=cache_bytes,
                              **(cache_kw or {}))
        return router

    R = max(1, int(replication_factor))
    n_tables = n_shards * R
    devs = mesh.devices.reshape(-1) if mesh is not None else None

    def make_table(j: int):
        if devs is None:
            return ShardedDeviceKVS(slot_bytes, n_slots)
        group = devs[j::n_tables]
        if len(group) == 0:                    # more tables than devices
            group = devs[j % len(devs):j % len(devs) + 1]
        sub = Mesh(np.asarray(group), ("kv",))
        return ShardedDeviceKVS(slot_bytes, n_slots, mesh=sub)

    if R == 1:
        return finish(ShardedKVS([make_table(i) for i in range(n_shards)]))
    shards = []
    for i in range(n_shards):
        replicas = [make_table(i * R + r) for r in range(R)]
        shards.append(ReplicatedKVS(
            replicas, write_quorum=1 if write_quorum is None else write_quorum,
            retry=retry))
    return finish(ShardedKVS(shards))
