"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Single pod: 16×16 = 256 chips,
axes (data, model).  Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model);
the pod axis folds into data-parallel/FSDP sharding via the default rules.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.35: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types parameter
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Small mesh for local smoke runs (1 device by default)."""
    return _make_mesh((n_data, n_model), ("data", "model"))
