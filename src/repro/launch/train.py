"""End-to-end training driver with RStore-versioned checkpoint/restart.

Examples:
  # ~100M-param model, a few hundred steps on CPU (examples/versioned_training)
  python -m repro.launch.train --arch smollm-360m --reduced --steps 50

  # resume after a crash (restores the newest RStore version; the
  # deterministic pipeline skips ahead, no data replay)
  python -m repro.launch.train --arch smollm-360m --reduced --steps 100 --resume

Fault-tolerance contract:
  - checkpoint commits are RStore versions (atomic at index publish, delta
    from the parent version → unchanged blocks dedupe);
  - --crash-at simulates a hard failure mid-run for the restart tests;
  - restarts may use a different mesh (train/elastic.py).
"""
from __future__ import annotations

import argparse
import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..data.pipeline import synthetic_batch
from ..models.model import build_model
from ..sharding.rules import mesh_env
from ..train.checkpoint import VersionedCheckpointer
from ..train.optimizer import make_optimizer
from ..train.train_step import init_state, make_train_step
from .mesh import make_debug_mesh


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--retain-last", type=int, default=0,
                    help="cap checkpoint storage: keep only the newest N "
                         "versions and compact after each commit (0 = keep "
                         "all)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a hard failure after N steps")
    ap.add_argument("--ckpt-state", default="/tmp/repro_ckpt_state.pkl",
                    help="host-side pickled checkpointer (stands in for the "
                         "shared RStore service)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32",
                           "remat": "none"})
    model = build_model(cfg)
    opt = make_optimizer(cfg, lr=args.lr)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    ckpt_path = Path(args.ckpt_state)
    start_step = 0
    if args.resume and ckpt_path.exists():
        ckpt, meta = pickle.loads(ckpt_path.read_bytes())
        state = init_state(cfg, opt, jax.random.PRNGKey(args.seed))
        state = ckpt.restore(meta["version"], like=state)
        start_step = meta["step"]
        print(f"[train] resumed at step {start_step} "
              f"(version {meta['version']})")
    else:
        ckpt = VersionedCheckpointer()
        state = init_state(cfg, opt, jax.random.PRNGKey(args.seed))
        v0 = ckpt.commit(state, parents=(), tag="init")
        pickle_meta(ckpt_path, ckpt, {"version": v0, "step": 0})

    last_version = ckpt.latest()
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = synthetic_batch(cfg, step, args.batch, args.seq)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if args.crash_at >= 0 and step + 1 >= args.crash_at:
            print(f"[train] simulated crash at step {step + 1}")
            raise SystemExit(17)
        if (step + 1) % args.checkpoint_every == 0 or step == args.steps - 1:
            v = ckpt.commit(state, parents=(last_version,),
                            tag=f"step{step + 1}")
            last_version = v
            if args.retain_last > 0:
                rep = ckpt.retain_last(args.retain_last)
                if rep.mode != "noop":
                    print(f"[train] compacted: -{rep.reclaimed_frac:.0%} "
                          f"stored bytes ({rep.chunks_deleted} chunks -> "
                          f"{rep.chunks_written})")
            pickle_meta(ckpt_path, ckpt, {"version": v, "step": step + 1})
            st = ckpt.storage_stats()
            print(f"[train] committed version {v} at step {step + 1} "
                  f"(chunks={st['n_chunks']}, "
                  f"stored={st['stored_chunk_bytes']/2**20:.1f} MiB)")
    print(f"[train] done: {args.steps} steps in {time.time()-t0:.1f}s")
    return ckpt, state


def pickle_meta(path: Path, ckpt, meta):
    path.write_bytes(pickle.dumps((ckpt, meta)))


if __name__ == "__main__":
    run()
