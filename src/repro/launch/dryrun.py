import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input-shape × mesh) cell: build the production
mesh, lower the appropriate step function over ShapeDtypeStruct inputs (zero
allocation), ``.compile()`` it, and record memory analysis, cost analysis and
the three-term roofline (benchmarks/roofline.py).  Results land in
``benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import functools
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, runnable
from ..data.pipeline import batch_spec
from ..models.model import abstract_cache, abstract_params, build_model
from ..sharding.rules import mesh_env
from ..train.optimizer import make_optimizer
from ..train.train_step import abstract_state, make_train_step
from .mesh import make_production_mesh

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"


def build_cell(cfg, shape, env):
    """Returns (jitted_fn, arg_specs) for one cell."""
    model = build_model(cfg)
    if shape.kind == "train":
        opt = make_optimizer(cfg)
        step = make_train_step(model, opt)
        state = abstract_state(cfg, opt, env)
        batch = batch_spec(cfg, shape.global_batch, shape.seq_len, env)
        return jax.jit(step, donate_argnums=(0,)), (state, batch)
    params = abstract_params(cfg, env)
    if shape.kind == "prefill":
        batch = batch_spec(cfg, shape.global_batch, shape.seq_len, env)
        fn = functools.partial(model.prefill, max_len=shape.seq_len)
        return jax.jit(fn), (params, batch)
    # decode: one new token against a seq_len cache
    caches = abstract_cache(cfg, shape.global_batch, shape.seq_len, env)
    tok_sh = env.sharding_for((shape.global_batch, 1), ("batch", None)) \
        if env else None
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                  sharding=tok_sh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jax.jit(model.decode_step, donate_argnums=(1,)), \
        (params, caches, tokens, pos)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False, out_dir: pathlib.Path = RESULTS_DIR,
             optimized: bool = False):
    import dataclasses

    from benchmarks import roofline as rl

    from ..configs.registry import OPTIMIZED

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    variant = ""
    if optimized:
        over = OPTIMIZED.get(arch, {}).get(shape.kind, {})
        if not over:
            return {"arch": arch, "shape": shape_name, "status": "skipped",
                    "reason": "no optimized overrides for this shape kind"}
        cfg = dataclasses.replace(cfg, **over)
        variant = "__opt"
    ok, why = runnable(cfg, shape)
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16") + variant
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_chips": int(n_chips), "status": "error"}
    t0 = time.time()
    try:
        from ..sharding.rules import rules_for
        with mesh_env(mesh, rules=rules_for(cfg, mesh)) as env:
            fn, args = build_cell(cfg, shape, env)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes") if hasattr(ma, k)}
        # per-device live-buffer estimate (arguments alias outputs via donation)
        mem["per_device_hbm_bytes"] = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0))

        roof = rl.analyze(compiled, cfg, shape.kind, shape.seq_len,
                          shape.global_batch, int(n_chips))
        rec.update(status="ok", lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), memory=mem,
                   roofline=roof.to_dict())
        if save_hlo:
            (out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.txt").write_text(
                compiled.as_text())
        print(f"[dryrun] OK {arch} × {shape_name} × {mesh_name}: "
              f"compile {t_compile:.1f}s, "
              f"hbm/dev {mem['per_device_hbm_bytes']/2**30:.2f} GiB, "
              f"bottleneck {roof.bottleneck}, "
              f"roofline {roof.roofline_fraction:.3f}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {arch} × {shape_name} × {mesh_name}: "
              f"{type(e).__name__}: {str(e)[:200]}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2×16×16 mesh (default: 16×16 single pod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-arch §Perf winning overrides")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    if args.optimized:
        from ..configs.registry import OPTIMIZED
        # the §Perf winners are train/prefill optimizations; decode cells are
        # already bandwidth-bound-optimal at baseline (and dp_only-style
        # profiles regress them) — scope the optimized sweep accordingly
        cells = [(a, s) for a, s in cells
                 if SHAPES[s].kind in OPTIMIZED.get(a, {})]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = ("pod2x16x16" if mp else "pod16x16") + \
                ("__opt" if args.optimized else "")
            path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_existing and path.exists():
                st = json.loads(path.read_text()).get("status")
                if st in ("ok", "skipped"):
                    continue
            rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                           out_dir=out_dir, optimized=args.optimized)
            failures += rec["status"] == "error"
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
