"""Optimizers: AdamW and factored Adafactor.

State trees mirror the parameter tree (same sharding specs), so ZeRO-style
optimizer-state sharding falls out of the FSDP parameter rules for free.
Adafactor (β1=0, factored second moment) is the default for the ≥100B archs —
AdamW's 12 bytes/param cannot fit a 1T-param model on one v5e pod.

``abstract_state`` builds ShapeDtypeStructs (with shardings) directly from
ParamDefs so the dry-run can lower a full train step without materializing
anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.layers import ParamDef


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"             # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95                # adafactor: decay exponent handled below
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_rms: float = 1.0           # adafactor update clipping


def _is_factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


class Optimizer:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- building
    def init(self, params):
        c = self.cfg
        if c.name == "adamw":
            return {
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32),
            }
        if c.name == "adafactor":
            def vr(p):
                return (jnp.zeros(p.shape[:-1], jnp.float32) if _is_factorable(p.shape)
                        else jnp.zeros(p.shape, jnp.float32))

            def vc(p):
                return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                        if _is_factorable(p.shape) else jnp.zeros((1,), jnp.float32))
            return {
                "vr": jax.tree.map(vr, params),
                "vc": jax.tree.map(vc, params),
                "step": jnp.zeros((), jnp.int32),
            }
        raise ValueError(c.name)

    def abstract_state(self, param_defs, env=None):
        """ShapeDtypeStructs for the optimizer state, from ParamDefs."""
        c = self.cfg
        is_def = lambda x: isinstance(x, ParamDef)

        def sds(shape, axes):
            sh = env.sharding_for(shape, axes) if env else None
            return jax.ShapeDtypeStruct(shape, jnp.float32, sharding=sh)

        if c.name == "adamw":
            full = lambda d: sds(d.shape, d.axes)
            return {
                "mu": jax.tree.map(full, param_defs, is_leaf=is_def),
                "nu": jax.tree.map(full, param_defs, is_leaf=is_def),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
        if c.name == "adafactor":
            def vr(d):
                return (sds(d.shape[:-1], d.axes[:-1]) if _is_factorable(d.shape)
                        else sds(d.shape, d.axes))

            def vc(d):
                return (sds(d.shape[:-2] + d.shape[-1:], d.axes[:-2] + d.axes[-1:])
                        if _is_factorable(d.shape) else sds((1,), (None,)))
            return {
                "vr": jax.tree.map(vr, param_defs, is_leaf=is_def),
                "vc": jax.tree.map(vc, param_defs, is_leaf=is_def),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
        raise ValueError(c.name)

    # --------------------------------------------------------------- update
    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1
        if c.name == "adamw":
            bc1 = 1.0 - c.b1 ** step.astype(jnp.float32)
            bc2 = 1.0 - c.b2 ** step.astype(jnp.float32)

            def upd(p, g, m, v):
                g32 = g.astype(jnp.float32)
                m = c.b1 * m + (1 - c.b1) * g32
                v = c.b2 * v + (1 - c.b2) * g32 * g32
                u = (m / bc1) / (jnp.sqrt(v / bc2) + c.eps)
                u = u + c.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - c.lr * u).astype(p.dtype), m, v

            out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
            new_p = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
            nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
            return new_p, {"mu": mu, "nu": nu, "step": step}

        # ---- adafactor ----
        decay = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(p, g, vr, vc):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + 1e-30
            if _is_factorable(p.shape):
                vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
                vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                vhat = (vr[..., None] / jnp.maximum(denom[..., None], 1e-30)) \
                    * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(vhat + c.eps)
            else:
                vr = decay * vr + (1 - decay) * g2
                u = g32 * jax.lax.rsqrt(vr + c.eps)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / c.clip_rms)
            u = u + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - c.lr * u).astype(p.dtype), vr, vc

        out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"vr": pick(1), "vc": pick(2), "step": step}


def make_optimizer(model_cfg, lr: float = 3e-4) -> Optimizer:
    return Optimizer(OptConfig(name=model_cfg.optimizer, lr=lr))
