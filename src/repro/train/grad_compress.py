"""Update/gradient compression built on the paper's delta machinery.

Two distributed-optimization tools reusing RStore's record-level compression
insight (beyond-paper integration, documented in DESIGN.md §9):

1. ``xor_delta_stats`` — measures how sparse consecutive parameter *updates*
   are at block granularity (the signal the checkpointer's dedupe exploits):
   blocks whose XOR-delta is zero are skipped at commit time.

2. ``compress_update`` / ``decompress_update`` — 8-bit quantization with
   per-block scales for cross-pod gradient exchange: the pod axis exchanges
   compressed updates (4× fewer ICI bytes on the slowest links).  Error
   feedback (the residual) keeps it convergent.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops


def xor_delta_stats(prev: np.ndarray, new: np.ndarray,
                    block_bytes: int = 1 << 16) -> Dict[str, float]:
    """Fraction of changed words/blocks between two flat byte buffers."""
    pb = prev.view(np.uint8)
    nb = new.view(np.uint8)
    n = min(len(pb), len(nb)) & ~3
    words = n // 4
    rows = max(1, words // (block_bytes // 4))
    w = (words // rows) & ~0 or 1
    pw = pb[:rows * w * 4].view(np.uint32).reshape(rows, w)
    nw = nb[:rows * w * 4].view(np.uint32).reshape(rows, w)
    _, changed = kops.xor_delta_batch(pw, nw)
    return {
        "changed_word_fraction": float(changed.sum()) / max(1, rows * w),
        "changed_block_fraction": float((changed > 0).sum()) / rows,
    }


def compress_update(u: jax.Array, block: int = 256
                    ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with per-block max scales."""
    flat = u.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decompress_update(q: jax.Array, scale: jax.Array, shape, dtype
                      ) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = int(np.prod(shape))
    return out[:n].reshape(shape).astype(dtype)


def compressed_allreduce_error_feedback(u: jax.Array, residual: jax.Array,
                                        axis_name: str):
    """Quantize (u + residual), psum the int8 payload, return the mean update
    and the new residual.  For use inside shard_map over the pod axis."""
    target = u + residual
    q, scale = compress_update(target)
    deq = decompress_update(q, scale, u.shape, jnp.float32)
    new_residual = target - deq
    summed = jax.lax.psum(deq, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return summed / n, new_residual
