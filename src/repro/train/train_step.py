"""The jitted train step: loss → grads → optimizer update.

State is a plain pytree {"params", "opt"} so jit donation, sharding and the
RStore checkpoint manager all treat it uniformly.  The same builder serves
real training (examples/launch) and the dry-run (abstract lowering).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model, abstract_params, build_model, init_params, param_defs
from .optimizer import Optimizer, make_optimizer


def make_train_step(model: Model, opt: Optimizer):
    def train_step(state, batch):
        def loss_fn(params):
            return model.loss(params, batch)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_params, new_opt = opt.update(grads, state["opt"], state["params"])
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(cfg: ModelConfig, opt: Optimizer, key):
    params = init_params(cfg, key)
    return {"params": params, "opt": opt.init(params)}


def abstract_state(cfg: ModelConfig, opt: Optimizer, env=None):
    """ShapeDtypeStruct state (with shardings) for AOT lowering."""
    defs = param_defs(cfg)
    return {
        "params": abstract_params(cfg, env),
        "opt": opt.abstract_state(defs, env),
    }
