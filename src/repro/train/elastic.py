"""Elastic restart: restore a checkpoint onto a *different* mesh.

RStore's chunk layout is mesh-independent (records are keyed by logical
tensor block, not by device), so growing/shrinking the cluster is: build the
new mesh → re-lower the train step under the new sharding rules → restore the
latest version and ``device_put`` each tensor with its new NamedSharding.
Partial restore (Q2) lets a data-parallel-only rescale fetch just the blocks
the new topology is missing, though the default path restores everything.

Failure handling contract (launch/train.py):
  - commits are atomic at RStore index publish; a crash mid-commit leaves the
    previous version intact;
  - on restart the driver calls ``restore_for_mesh`` with whatever devices
    are healthy; the deterministic data pipeline skips ahead to the stored
    step, so no samples repeat.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from ..models.config import ModelConfig
from ..models.model import param_defs
from ..models.layers import ParamDef, tree_pspecs
from ..sharding.rules import MeshEnv, default_rules, mesh_env
from .checkpoint import VersionedCheckpointer
from .optimizer import Optimizer


def shard_state_for_mesh(state_host, cfg: ModelConfig, opt: Optimizer,
                         mesh) -> dict:
    """device_put a host state pytree with shardings derived for ``mesh``."""
    env = MeshEnv(mesh=mesh, rules=default_rules(mesh))
    defs = param_defs(cfg)
    pspecs = {
        "params": tree_pspecs(defs, env),
        "opt": jax.tree.map(lambda s: env.sharding_for(s.shape, getattr(s, "axes", (None,) * len(s.shape)))
                            if hasattr(s, "shape") else None,
                            opt.abstract_state(defs, env)),
    }

    def put(x, sh):
        try:
            return jax.device_put(x, sh)
        except Exception:
            return jax.device_put(x)   # replicate anything unshardable

    return {
        "params": jax.tree.map(put, state_host["params"], pspecs["params"]),
        "opt": jax.tree.map(lambda x: jax.device_put(x), state_host["opt"]),
    }


def restore_for_mesh(ckpt: VersionedCheckpointer, version: int, like_state,
                     cfg: ModelConfig, opt: Optimizer, mesh):
    """Q1 restore + reshard onto a (possibly different) mesh."""
    host_state = ckpt.restore(version, like=like_state)
    return shard_state_for_mesh(host_state, cfg, opt, mesh)
