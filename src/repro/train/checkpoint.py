"""RStore-backed versioned checkpointing — the paper's store as the
framework's artifact layer.

Every checkpoint commit is an RStore *version*; every tensor block is a keyed
*record* (primary key = stable hash of ``(tensor_path, block_idx)``).  Blocks
whose bytes did not change since the parent version dedupe automatically
(frozen layers, EMA snapshots, skipped-update schedules); branched experiment
forks form the version DAG.  Queries map onto training operations:

  Q1 full version retrieval   → restore(version)
  Q.records multi-point batch → partial restore (elastic rescale: only the
                                blocks a new mesh shard needs, one batched
                                session → one KVS round trip)
  Q3 record evolution         → per-tensor training forensics

The commit path is asynchronous-friendly: deltas land in RStore's delta store
(host) and are chunked per batch off the training step's critical path (§4).
``commit_many`` stages a whole run segment (e.g. every step of an
accumulation window) through one :class:`repro.core.WriteSession`: all of
its chunk/map writes reach the backend as one group commit — one write
round trip per shard under ``ShardedKVS``.
"""
from __future__ import annotations

import hashlib
import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Q, RStore, RStoreConfig


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _block_key(tensor_path: str, block_idx: int) -> int:
    h = hashlib.blake2b(f"{tensor_path}#{block_idx}".encode(),
                        digest_size=4).digest()
    return int.from_bytes(h, "big") & 0x7FFFFFFF


@dataclass
class TensorMeta:
    path: str
    shape: Tuple[int, ...]
    dtype: str
    n_blocks: int
    block_keys: List[int]


class VersionedCheckpointer:
    """Commit/restore pytree states through an RStore instance."""

    def __init__(self, store: Optional[RStore] = None,
                 block_bytes: int = 1 << 20,
                 rstore_config: Optional[RStoreConfig] = None) -> None:
        self.block_bytes = int(block_bytes)
        self.rs = store or RStore(rstore_config or RStoreConfig(
            algorithm="bottom_up", capacity=4 << 20, batch_size=8,
            store_payloads=True))
        self.meta: Dict[int, Dict[str, TensorMeta]] = {}   # version -> metas
        self.tags: Dict[str, int] = {}   # tag -> newest version committed under it
        self._key_to_block: Dict[int, Tuple[str, int]] = {}
        self._root: Optional[int] = None

    # -------------------------------------------------------------- commits
    def _blocks_of(self, path: str, arr: np.ndarray):
        raw = np.ascontiguousarray(arr).tobytes()
        n = max(1, (len(raw) + self.block_bytes - 1) // self.block_bytes)
        for i in range(n):
            yield i, raw[i * self.block_bytes:(i + 1) * self.block_bytes]

    def _delta_of(self, state, parents: Sequence[int],
                  parent_payload: Optional[Dict[int, bytes]] = None):
        """(adds, dels, metas, child_payload) for committing ``state``
        against ``parents``.

        Only blocks whose bytes differ from the first parent are added —
        the delta the paper's ingest path expects.  ``parent_payload``
        (pk -> bytes of the parent's live blocks) is resolved from the
        store when not given; chained callers pass the previous state's
        returned ``child_payload`` so a K-step chain does O(K·delta) work,
        not K full key-map/payload rebuilds."""
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        metas: Dict[str, TensorMeta] = {}
        adds: Dict[int, bytes] = {}
        all_keys: set = set()
        if parent_payload is None:
            parent_payload = {}
            if parents:
                # compare against the parent's live records
                pm = self.rs._key_map(parents[0])
                store = self.rs.graph.store
                parent_payload = {pk: store.payload(rid)
                                  for pk, rid in pm.items()}
        child_payload: Dict[int, bytes] = {}

        for path, leaf in flat:
            pstr = _path_str(path)
            arr = np.asarray(leaf)
            keys = []
            for bi, blob in self._blocks_of(pstr, arr):
                pk = _block_key(pstr, bi)
                if pk in all_keys or (pk in self._key_to_block and
                                      self._key_to_block[pk] != (pstr, bi)):
                    raise RuntimeError(f"block key collision for {pstr}#{bi}")
                all_keys.add(pk)
                self._key_to_block[pk] = (pstr, bi)
                keys.append(pk)
                child_payload[pk] = blob
                if parent_payload.get(pk) != blob:
                    adds[pk] = blob
            metas[pstr] = TensorMeta(pstr, tuple(arr.shape), str(arr.dtype),
                                     len(keys), keys)
        dels = [pk for pk in parent_payload if pk not in all_keys]
        return adds, dels, metas, child_payload

    def _commit_into(self, writer, state, parents: Sequence[int],
                     tag: str = "",
                     parent_payload: Optional[Dict[int, bytes]] = None):
        adds, dels, metas, child_payload = self._delta_of(
            state, parents, parent_payload)
        if not parents:
            vid = writer.init_root(adds)
        else:
            vid = writer.commit(list(parents), adds=adds, dels=dels)
        self.meta[vid] = metas
        if tag:
            self.tags[tag] = vid
        if self._root is None:
            self._root = vid
        return vid, child_payload

    def commit(self, state, parents: Sequence[int] = (),
               tag: str = "") -> int:
        """Commit a pytree as a new version derived from ``parents`` (a
        one-commit write session; flushing follows the store's batching)."""
        with self.rs.writer(flush_on_close=False) as w:
            return self._commit_into(w, state, parents, tag)[0]

    def commit_many(self, states: Sequence, parents: Sequence[int] = (),
                    tag: str = "") -> List[int]:
        """Commit a chain of pytree states in ONE write session.

        Each state's parent is the previous one (the first hangs off
        ``parents``); the session group-flushes on exit, so the whole
        chain's chunks and maps cost one backend write round trip per
        shard.  The parent payload map is carried forward along the chain
        instead of rebuilt per commit."""
        if not states:      # don't open (and group-flush) a writer for a no-op
            return []
        vids: List[int] = []
        with self.rs.writer() as w:
            chain = list(parents)
            carried: Optional[Dict[int, bytes]] = None
            for state in states:
                vid, carried = self._commit_into(w, state, tuple(chain), tag,
                                                 parent_payload=carried)
                chain = [vid]
                vids.append(vid)
        return vids

    # ------------------------------------------------------------ retention
    def _apply_retention(self, policy, compact: bool):
        from ..core.compact import CompactionReport, Compactor
        retired = set(self.rs.retain(policy))
        for v in retired:
            self.meta.pop(v, None)
        self.tags = {t: v for t, v in self.tags.items() if v not in retired}
        if not compact:
            return None
        # cost-model gate: called after every checkpoint commit, so only
        # pay the rewrite once enough stored bytes are dead or the layout
        # fragmented — not on every step
        cp = Compactor(self.rs)
        if cp.should_run():
            return cp.run_pass()
        return CompactionReport(mode="noop",
                                layout_epoch=self.rs.layout_epoch)

    def retain_last(self, k: int, compact: bool = True):
        """Cap checkpoint storage: keep only the most recent ``k`` committed
        versions and (by default) run a compaction pass — gated by the
        :meth:`Compactor.should_run` cost model — so the dropped
        checkpoints' record copies are physically reclaimed from the KVS.
        Returns the :class:`~repro.core.compact.CompactionReport` (or None
        with ``compact=False``).  The training loop calls this after each
        checkpoint commit (``launch/train.py --retain-last``)."""
        from ..core.compact import keep_last
        return self._apply_retention(keep_last(k), compact)

    def retain_tagged(self, tags: Sequence[str], compact: bool = True):
        """Keep only the checkpoints committed under ``tags`` (the consumer
        of ``commit(..., tag=...)``): pinned milestones survive, everything
        else is pruned and compacted away."""
        from ..core.compact import keep_tagged
        missing = [t for t in tags if t not in self.tags]
        if missing:
            raise KeyError(f"unknown checkpoint tag(s) {missing}")
        return self._apply_retention(
            keep_tagged([self.tags[t] for t in tags]), compact)

    # -------------------------------------------------------------- restore
    def restore(self, vid: int, like=None):
        """Q1: full version retrieval → pytree (one-query session)."""
        res = self.rs.snapshot().execute([Q.version(vid)])
        return self._assemble(vid, res[0].value, like)

    def restore_tensors(self, vid: int, prefixes: Sequence[str]):
        """Partial restore: only tensors matching prefixes.

        Block keys are hashed (not contiguous), so each tensor is a
        multi-point ``Q.records`` query; the whole restore is ONE batched
        session — every selected tensor's blocks arrive in a single KVS
        round trip (the seed issued one get_record per block)."""
        metas = self.meta[vid]
        selected = [(pstr, tm) for pstr, tm in metas.items()
                    if any(pstr.startswith(p) for p in prefixes)]
        if not selected:
            return {}
        res = self.rs.snapshot().execute(
            [Q.records(vid, tm.block_keys) for _, tm in selected])
        out: Dict[str, np.ndarray] = {}
        for (pstr, tm), r in zip(selected, res):
            blobs = []
            for pk in tm.block_keys:
                assert pk in r.value, f"missing block {pstr}"
                blobs.append(r.value[pk])
            out[pstr] = self._tensor_from(tm, blobs)
        return out

    def evolution(self, tensor_path: str, block_idx: int = 0):
        """Q3: every distinct value a block ever had (origin order)."""
        pk = _block_key(tensor_path, block_idx)
        evo, _ = self.rs.get_evolution(pk)
        return evo

    # ------------------------------------------------------------- plumbing
    def _tensor_from(self, tm: TensorMeta, blobs: List[bytes]) -> np.ndarray:
        raw = b"".join(blobs)
        return np.frombuffer(raw, dtype=np.dtype(tm.dtype)).reshape(tm.shape).copy()

    def _assemble(self, vid: int, records: Dict[int, bytes], like):
        metas = self.meta[vid]
        tensors = {}
        for pstr, tm in metas.items():
            blobs = [records[pk] for pk in tm.block_keys]
            tensors[pstr] = self._tensor_from(tm, blobs)
        if like is None:
            return tensors
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            arr = tensors[_path_str(path)]
            leaves.append(jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)

    def latest(self) -> Optional[int]:
        vs = self.rs.graph.versions
        return vs[-1] if vs else None

    def storage_stats(self):
        return self.rs.storage_stats()
