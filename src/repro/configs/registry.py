"""Assigned architecture registry: exact configs from the assignment table.

Every entry is selectable via ``--arch <id>`` in the launchers.  Per-arch
divergences from upstream implementations are recorded in ``notes`` and in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Dict, List

from ..models.config import ModelConfig

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- [ssm] SSD (state-space duality), arXiv:2405.21060 ----------------------
mamba2_130m = _register(ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    vocab_size=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    conv_width=4, tie_embeddings=True, d_ff=0, optimizer="adamw",
    notes="attention-free; runs long_500k (sub-quadratic decode state)"))

# --- [dense] InternLM2-20B, arXiv:2403.17297 --------------------------------
internlm2_20b = _register(ModelConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=92544,
    rope_theta=1e6))

# --- [dense] SmolLM-360M (llama-arch small) ---------------------------------
smollm_360m = _register(ModelConfig(
    name="smollm-360m", family="dense", n_layers=32, d_model=960,
    n_heads=15, n_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=49152,
    tie_embeddings=True, rope_theta=1e4,
    notes="15 heads indivisible by 16-way TP → attention TP falls back to "
          "replication (rules drop non-dividing assignments); MLP/vocab shard"))

# --- [dense] Qwen2.5-32B (GQA, QKV bias) ------------------------------------
qwen2_5_32b = _register(ModelConfig(
    name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    notes="40 heads % 16 != 0 → attention heads replicated under TP; the "
          "27648-wide MLP (84% of layer FLOPs) keeps full TP"))

# --- [dense] StableLM-2-1.6B (MHA kv=32) ------------------------------------
stablelm_1_6b = _register(ModelConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632, vocab_size=100352,
    rope_theta=1e4,
    notes="upstream uses partial-rotary (25%); we apply full RoPE (documented)"))

# --- [audio] Whisper-base enc-dec, arXiv:2212.04356 -------------------------
whisper_base = _register(ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, n_encoder_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
    vocab_size=51865, act="gelu", use_rope=False, tie_embeddings=True,
    notes="conv frontend stubbed: input_specs feeds precomputed frame "
          "embeddings (B,S,D); learned abs pos; RMSNorm in place of LN"))

# --- [hybrid] Jamba-1.5-large 398B, arXiv:2403.19887 ------------------------
jamba_1_5_large = _register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576, vocab_size=65536,
    n_experts=16, moe_top_k=2, moe_layer_period=2, moe_layer_offset=1,
    attn_layer_period=8, attn_layer_offset=4,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    optimizer="adafactor", use_rope=False,
    notes="mamba+attn 1:7 interleave, MoE every other layer; upstream uses "
          "Mamba-1 + no positional encoding — we use the SSD (Mamba-2) mixer "
          "uniformly and no RoPE (matching Jamba); adafactor (398B params "
          "cannot carry AdamW state on one v5e pod); runs long_500k"))

# --- [moe] Granite-3.0-1B-A400M ----------------------------------------------
granite_moe_1b = _register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, head_dim=64, d_ff=512, d_ff_expert=512,
    vocab_size=49155, n_experts=32, moe_top_k=8, tie_embeddings=True,
    rope_theta=1e4))

# --- [moe] Kimi-K2 1T-A32B (paper-table) -------------------------------------
kimi_k2_1t = _register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=2048, d_ff_expert=2048,
    vocab_size=163840, n_experts=384, moe_top_k=8,
    optimizer="adafactor", rope_theta=1e6,
    notes="assignment specifies GQA kv=8 (real K2 uses MLA — we follow the "
          "assignment); adafactor: 1T params exceed AdamW state on 256 chips; "
          "train_4k memory needs the 512-chip multi-pod mesh (see roofline)"))

# --- [vlm] InternVL2-26B (InternViT stub + InternLM2-20B backbone) -----------
internvl2_26b = _register(ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=92553,
    n_prefix_embeds=1024, rope_theta=1e6,
    notes="ViT frontend stubbed: input_specs feeds 1024 precomputed patch "
          "embeddings per sample; backbone = InternLM2-20B"))


# Beyond-paper optimized variants (§Perf hillclimb winners), per shape kind.
# The registry configs stay the paper-faithful baselines; these overrides are
# applied by ``dryrun --optimized`` and recorded separately in EXPERIMENTS.md.
# Scoping is measured, not assumed: dp_only requires global_batch ≥ chips
# (train_4k only — prefill_32k's batch of 32 would replicate 256×), and
# shard_map MoE wins on train+prefill but regresses single-token decode
# (gspmd fallback built into moe_shard_map).
_DP_ONLY_TRAIN = {"train": {"sharding_profile": "dp_only"}}
_SHARD_MAP_MOE = {"train": {"moe_impl": "shard_map"},
                  "prefill": {"moe_impl": "shard_map"}}
OPTIMIZED = {
    "smollm-360m": {"train": {"sharding_profile": "dp_only",
                              "remat": "dots_nb"}},
    "granite-moe-1b-a400m": _SHARD_MAP_MOE,
    "kimi-k2-1t-a32b": _SHARD_MAP_MOE,
    "jamba-1.5-large-398b": _SHARD_MAP_MOE,
    "mamba2-130m": _DP_ONLY_TRAIN,
    "whisper-base": _DP_ONLY_TRAIN,
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def names() -> List[str]:
    return list(ARCHS)
