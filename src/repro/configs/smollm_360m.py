"""--arch smollm-360m (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["smollm-360m"]
