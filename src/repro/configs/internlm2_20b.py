"""--arch internlm2-20b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["internlm2-20b"]
