"""--arch jamba-1.5-large-398b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["jamba-1.5-large-398b"]
