"""--arch internvl2-26b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["internvl2-26b"]
