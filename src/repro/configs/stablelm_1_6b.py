"""--arch stablelm-1.6b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["stablelm-1.6b"]
