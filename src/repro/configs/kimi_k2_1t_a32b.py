"""--arch kimi-k2-1t-a32b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["kimi-k2-1t-a32b"]
