"""--arch whisper-base (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["whisper-base"]
