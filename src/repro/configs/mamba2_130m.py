"""--arch mamba2-130m (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["mamba2-130m"]
