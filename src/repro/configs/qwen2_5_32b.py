"""--arch qwen2.5-32b (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["qwen2.5-32b"]
