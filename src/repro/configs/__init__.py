from .registry import ARCHS, get, names
from .shapes import SHAPES, ShapeSpec, cells, runnable

__all__ = ["ARCHS", "get", "names", "SHAPES", "ShapeSpec", "cells", "runnable"]
