"""--arch granite-moe-1b-a400m (see registry for the full spec)."""
from .registry import ARCHS

CONFIG = ARCHS["granite-moe-1b-a400m"]
