"""Assigned input-shape cells and per-arch applicability."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch × shape) is a runnable cell, with the skip reason.

    long_500k requires sub-quadratic attention: run for SSM/hybrid only —
    pure full-attention archs skip it (recorded in DESIGN.md).  No assigned
    arch is encoder-only, so decode shapes run everywhere else.
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "SKIP(full-attention)"
    return True, ""


def cells(archs: Dict[str, ModelConfig]) -> List[Tuple[str, str, bool, str]]:
    out = []
    for a, cfg in archs.items():
        for s, sh in SHAPES.items():
            ok, why = runnable(cfg, sh)
            out.append((a, s, ok, why))
    return out
