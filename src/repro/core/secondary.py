"""Secondary attribute indexes: filtered scans without full-version fetches.

The paper places RStore "as a layer on top of a distributed key-value store
that houses the raw data as well as any indexes" — but until now only the
primary key was indexed (``Projections.key_chunks``), so a value-predicate
query ("all records of version v where field X = y") had to fetch the whole
version and scan it.  This module adds the missing index family, resolved
the RStore way: postings are *lossy chunk-granularity* lists, exactly like
the primary projections (§2.4), so the index stays small, updates are
append-mostly, and the query side reuses the bitmap-AND machinery — a
``Q.where`` plan is secondary-bitmap ∧ version-bitmap through the same
single ``and_popcount_batch`` kernel launch that plans the rest of the
session (``Projections.and_version_batch``).  Lossiness never leaks into
results: fetched chunks are post-filtered exactly against the extracted
attribute values (the same contract the paper states for the primary
projections — "a fetched chunk may turn out to hold no relevant record").

Three pieces:

- :class:`AttributeExtractor` — any callable ``payload -> {attr: int}``.
  Records whose extractor omits an attribute are simply unindexed for it.
  :func:`struct_extractor` builds the common case: fixed-offset
  little-endian unsigned integer fields, which makes ``datagen`` payloads
  (``DatasetSpec.attr_fields``) indexable out of the box.

- :class:`SecondaryIndex` — per-attribute ``value -> sorted chunk ids``
  postings, delta+varint compressed for persistence (``varint_encode``,
  the same inverted-index-literature encoding the primary projections
  report sizes with) and hash-bucketed into the backend keyspace under
  ``idx2/{attr}/{bucket}`` keys.  Because the postings live behind the
  :class:`~repro.core.kvs.Backend` protocol they ride ``ShardedKVS``
  sharding, ``ReplicatedKVS`` replication, and ``CachingKVS`` caching for
  free, and their bytes are priced by ``storage_stats()``.

- Maintenance hooks — every mutation path keeps postings coherent inside
  its existing round trips: ``WriteSession.flush``/online ingest extend
  postings for the batch's new chunks (dirty buckets join the flush's ONE
  ``multiput``), ``build()`` and ``Compactor.run_pass`` rewrite superseded
  postings inside the same staged multiput/multidelete as the chunk
  rewrite (so the layout-epoch bump, ``snapshot.refresh()`` semantics and
  ``CachingKVS`` invalidation carry over unchanged), and retention
  composes through the existing retained-version mask — retired versions
  fail at plan time, and dead record copies are dropped by the exact
  post-filter until compaction physically reclaims them.
"""
from __future__ import annotations

import struct
from typing import (Callable, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple)

import numpy as np

from .index import varint_decode, varint_encode

IDX2_PREFIX = "idx2"


class AttributeExtractor(Protocol):
    """Pulls integer attribute values out of an opaque record payload.

    Returns ``{attr_name: value}``; attributes absent from the dict leave
    the record unindexed for them (and excluded from exact post-filtering).
    """

    def __call__(self, payload: bytes) -> Dict[str, int]: ...


def struct_extractor(fields: Dict[str, Tuple[int, int]]) -> AttributeExtractor:
    """Built-in extractor for fixed-offset binary layouts.

    ``fields`` maps attribute name -> ``(byte_offset, byte_width)``; each
    field is read as a little-endian unsigned integer.  Payloads too short
    for a field simply omit it (mixed-schema stores stay indexable).
    """
    items = [(name, int(off), int(width)) for name, (off, width)
             in fields.items()]
    for name, off, width in items:
        if off < 0 or width < 1 or width > 8:
            raise ValueError(f"field {name!r}: bad (offset, width) "
                             f"({off}, {width})")

    def extract(payload: bytes) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for name, off, width in items:
            if len(payload) >= off + width:
                out[name] = int.from_bytes(payload[off:off + width], "little")
        return out

    return extract


def datagen_extractor(n_fields: int) -> AttributeExtractor:
    """Extractor matching :class:`~repro.core.datagen.DatasetSpec`'s
    ``attr_fields`` payload layout: ``n_fields`` little-endian uint32
    values at the start of the payload, named ``f0 .. f{n-1}``."""
    return struct_extractor({f"f{i}": (4 * i, 4) for i in range(n_fields)})


# ---------------------------------------------------------------- the index
class SecondaryIndex:
    """Lossy chunk-granularity postings for one extracted attribute.

    ``postings`` maps each observed attribute value to the sorted chunk ids
    that *may* hold a record with that value (lossy: the record copies in
    the chunk may all be dead, or live only in other versions — the exact
    answer is recovered by post-filtering fetched chunks).  A reverse map
    ``chunk_values`` (chunk id -> values it contributed) makes compaction
    removal O(affected) instead of a full posting scan.

    Persistence is bucketed: values hash into ``n_buckets`` buckets, each
    stored under ``idx2/{attr}/{bucket}`` as a blob of delta+varint
    compressed posting lists.  Mutators mark buckets dirty;
    :meth:`stage_writes` drains them as ``(key, blob)`` writes plus keys of
    now-empty buckets to delete, which the caller folds into the multiput /
    multidelete round trips it was already paying.
    """

    def __init__(self, attr: str, extractor: AttributeExtractor,
                 n_buckets: int = 16) -> None:
        if n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")
        self.attr = str(attr)
        self.extractor = extractor
        self.n_buckets = int(n_buckets)
        self.postings: Dict[int, np.ndarray] = {}     # value -> sorted cids
        self.chunk_values: Dict[int, np.ndarray] = {} # cid -> sorted values
        # cid -> (values int64, present bool) aligned to the chunk's stored
        # record order (row i of the chunk map).  This is what lets the
        # planner's index-only aggregates and composite post-filters be
        # *exact* without fetching the payload blob: the values were
        # extracted from the same payloads at index-maintenance time.
        self.chunk_record_values: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._dirty: set = set()                      # bucket ids to persist
        self._stored: set = set()                     # bucket ids with a live key
        self._bucket_bytes: Dict[int, int] = {}       # persisted blob sizes
        # sorted distinct-value cache for range predicates — same explicit
        # dirty-flag contract as Projections.sorted_keys
        self._sorted_values: Optional[np.ndarray] = None
        self._values_dirty = True

    # ------------------------------------------------------------- keyspace
    def bucket_of(self, value: int) -> int:
        return int(value) % self.n_buckets

    def key_of(self, bucket: int) -> str:
        return f"{IDX2_PREFIX}/{self.attr}/{bucket}"

    def stored_keys(self) -> List[str]:
        """Backend keys currently holding this index's buckets."""
        return [self.key_of(b) for b in sorted(self._stored)]

    # -------------------------------------------------------------- queries
    def postings_for(self, value: int) -> np.ndarray:
        """Chunk ids that may hold a record with ``attr == value``."""
        return self.postings.get(int(value), np.empty(0, np.int64))

    def sorted_values(self) -> np.ndarray:
        """All indexed attribute values, sorted (dirty-flag cached)."""
        if self._sorted_values is None or self._values_dirty:
            self._sorted_values = np.sort(np.fromiter(
                self.postings.keys(), dtype=np.int64, count=len(self.postings)))
            self._values_dirty = False
        return self._sorted_values

    def postings_in_range(self, lo: int, hi: int) -> List[np.ndarray]:
        """Posting lists of every indexed value in ``[lo, hi]`` —
        O(log n + m) via searchsorted over the sorted value array."""
        vs = self.sorted_values()
        a = np.searchsorted(vs, int(lo), side="left")
        b = np.searchsorted(vs, int(hi), side="right")
        return [self.postings[int(v)] for v in vs[a:b]]

    # -------------------------------------------------- per-record values
    def _record_values_of(self, rids: np.ndarray,
                          payload_of: Callable[[int], bytes]
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Extract ``(values, present)`` per record, in ``rids`` order —
        which is the chunk's stored order (row i of its chunk map)."""
        vals = np.zeros(len(rids), dtype=np.int64)
        present = np.zeros(len(rids), dtype=bool)
        for i, r in enumerate(rids):
            v = self.extractor(payload_of(int(r))).get(self.attr)
            if v is not None:
                vals[i] = int(v)
                present[i] = True
        return vals, present

    def record_values(self, cid: int) -> Tuple[np.ndarray, np.ndarray]:
        """``(values int64, present bool)`` aligned to chunk ``cid``'s
        stored record order — the exact per-record attribute values the
        planner's answer layer filters with (no payload fetch needed)."""
        return self.chunk_record_values[int(cid)]

    # ---------------------------------------------------------- maintenance
    def add_chunks(self, chunks: Iterable[Tuple[int, np.ndarray]],
                   payload_of: Callable[[int], bytes]) -> None:
        """Extend postings for freshly written chunks (flush / compaction
        rewrite).  Append-only: never empties a bucket."""
        for cid, rids in chunks:
            cid = int(cid)
            rvals, rpres = self._record_values_of(np.asarray(rids), payload_of)
            self.chunk_record_values[cid] = (rvals, rpres)
            vals = np.unique(rvals[rpres])
            if not len(vals):
                self.chunk_values[cid] = vals
                continue
            self.chunk_values[cid] = vals
            for v in vals.tolist():
                old = self.postings.get(v)
                if old is None:
                    self.postings[v] = np.asarray([cid], dtype=np.int64)
                    self._values_dirty = True
                else:
                    self.postings[v] = np.union1d(old, [cid])
                self._dirty.add(self.bucket_of(v))

    def remove_chunks(self, cids: Iterable[int]) -> None:
        """Retire superseded chunks from every posting list (compaction GC).
        O(values actually present in the removed chunks), via the reverse
        map."""
        for cid in cids:
            cid = int(cid)
            self.chunk_record_values.pop(cid, None)
            vals = self.chunk_values.pop(cid, None)
            if vals is None:
                continue
            for v in vals.tolist():
                old = self.postings.get(v)
                if old is None:
                    continue
                kept = old[old != cid]
                if len(kept):
                    self.postings[v] = kept
                else:
                    del self.postings[v]
                    self._values_dirty = True
                self._dirty.add(self.bucket_of(v))

    def rebuild(self, chunk_records: Dict[int, np.ndarray],
                payload_of: Callable[[int], bytes]) -> None:
        """Recompute postings from scratch (full ``build()`` path).  Every
        bucket that holds data — or held data before — is marked dirty so
        :meth:`stage_writes` rewrites or deletes it."""
        previously = {self.bucket_of(v) for v in self.postings}
        self.postings = {}
        self.chunk_values = {}
        self.chunk_record_values = {}
        self._values_dirty = True
        self.add_chunks(sorted(chunk_records.items()), payload_of)
        self._dirty |= previously | self._stored

    # ---------------------------------------------------------- persistence
    def _encode_bucket(self, bucket: int) -> bytes:
        vals = sorted(v for v in self.postings
                      if self.bucket_of(v) == bucket)
        parts = [struct.pack("<I", len(vals))]
        for v in vals:
            enc = varint_encode(self.postings[v])
            parts.append(struct.pack("<qI", v, len(enc)))
            parts.append(enc)
        return b"".join(parts)

    @staticmethod
    def decode_bucket(blob: bytes) -> Dict[int, np.ndarray]:
        """Inverse of the bucket encoding: ``{value: sorted chunk ids}``."""
        (n,) = struct.unpack_from("<I", blob, 0)
        off = 4
        out: Dict[int, np.ndarray] = {}
        for _ in range(n):
            v, nb = struct.unpack_from("<qI", blob, off)
            off += 12
            out[int(v)] = varint_decode(blob[off:off + nb])
            off += nb
        return out

    def stage_writes(self) -> Tuple[List[Tuple[str, bytes]], List[str]]:
        """Drain dirty buckets into ``(writes, deletes)`` for the caller's
        already-staged multiput/multidelete round trips.  Buckets that
        still hold values are (re)written; buckets that emptied out are
        deleted (only if they have a live backend key — no orphans, no
        spurious deletes)."""
        writes: List[Tuple[str, bytes]] = []
        deletes: List[str] = []
        live = {self.bucket_of(v) for v in self.postings}
        for b in sorted(self._dirty):
            if b in live:
                blob = self._encode_bucket(b)
                writes.append((self.key_of(b), blob))
                self._bucket_bytes[b] = len(blob)
                self._stored.add(b)
            elif b in self._stored:
                deletes.append(self.key_of(b))
                self._stored.discard(b)
                self._bucket_bytes.pop(b, None)
        self._dirty.clear()
        return writes, deletes

    @classmethod
    def load(cls, kvs, attr: str, extractor: AttributeExtractor,
             chunk_records: Dict[int, np.ndarray],
             payload_of: Callable[[int], bytes],
             n_buckets: int = 16) -> "SecondaryIndex":
        """Rehydrate an index from its persisted ``idx2/`` buckets (ONE
        multiget round trip), then rebuild the reverse chunk->values map
        from the store — the postings themselves come from the backend, so
        a persisted index round-trips without re-extracting every payload.
        """
        idx = cls(attr, extractor, n_buckets=n_buckets)
        present = [b for b in range(idx.n_buckets) if idx.key_of(b) in kvs]
        blobs = kvs.multiget([idx.key_of(b) for b in present])
        for b, blob in zip(present, blobs):
            idx.postings.update(SecondaryIndex.decode_bucket(blob))
            idx._stored.add(b)
            idx._bucket_bytes[b] = len(blob)
        idx._values_dirty = True
        for cid, rids in chunk_records.items():
            rvals, rpres = idx._record_values_of(np.asarray(rids), payload_of)
            idx.chunk_record_values[int(cid)] = (rvals, rpres)
            idx.chunk_values[int(cid)] = np.unique(rvals[rpres])
        return idx

    # ---------------------------------------------------------------- stats
    def stored_bytes(self) -> int:
        """Persisted posting bytes (what ``storage_stats()`` prices)."""
        return int(sum(self._bucket_bytes.values()))

    def report(self) -> Dict[str, int]:
        return {
            "n_values": len(self.postings),
            "n_postings": int(sum(len(p) for p in self.postings.values())),
            "n_buckets_stored": len(self._stored),
            "stored_bytes": self.stored_bytes(),
        }
