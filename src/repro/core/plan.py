"""Unified query planner: logical plan IR → bitmap program → answer layer.

The read path used to be a flat ``kind``-string switch duplicated across
``Snapshot.plan`` / ``execute`` / ``_extract`` / ``prefetch``.  This module
is the refactor of that path into three explicit layers (the plan-time
query/storage trade-off the versioned-dictionary literature — Byde & Twigg —
argues is where such systems are won or lost):

1. **Logical plan IR.**  :class:`Query` (built via :class:`Q`) now forms
   *trees*: the leaf retrieval classes (§2.4) plus composable predicates
   ``Q.and_ / Q.or_ / Q.not_`` over ``where``/``where_range``/``range``/
   ``records``/``record`` and aggregates ``Q.count / Q.exists /
   Q.distinct``.  :func:`normalize` flattens nested same-op nodes, drops
   duplicate children, and cancels double negation; the planner refuses
   retired versions and unindexed attributes at plan time.

2. **Physical bitmap program.**  Per batch, every distinct leaf predicate
   contributes ONE bitmap row (duplicate leaves across the batch share it),
   and each query's predicate tree compiles to AND/OR instructions over
   those rows — constant-folded against the two lattice extremes (a leaf
   with no postings is ``EMPTY``; a ``not_`` node is ``UNIVERSE`` at chunk
   granularity, because a record-level complement says nothing about which
   *chunks* to skip).  The whole batch then executes as ONE fused
   ``bitmap_vm_batch`` launch (``kernels/bitmap.py``), roots AND'd with
   their version bitmaps.  Version/evolution posting lists stay host-side
   (no kernel needed), except evolution under retention, which joins the
   launch to AND away chunks no retained version keeps.

3. **Fetch/answer layer.**  Each planned query carries a *mode*:
   ``"metadata"`` (aggregates over primary-key predicates — answered from
   the version graph, zero KVS traffic), ``"index_only"`` (aggregates
   touching indexed attributes — fetch chunk *maps* only, never payload
   blobs: exactness comes from the per-record attribute values the
   :class:`~repro.core.secondary.SecondaryIndex` keeps per chunk), or
   ``"fetch"`` (everything returning records — payloads + maps in the
   session's single interleaved multiget, post-filtered exactly per
   record).  :func:`answer` is the ONE per-kind switch left in the system.

``Snapshot`` (:mod:`repro.core.api`) wires these layers to the KVS and is
re-exported unchanged; ``Snapshot.explain`` renders the chosen plans with
predicted chunk/round-trip costs from :mod:`repro.core.costmodel`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from ..kernels import bitmap as kbitmap
from ..kernels import ops as kops
from .index import Projections, _bitmap_to_ids
from .types import unpack_ck

# Query-kind families.  Predicates return record sets and may nest under
# and/or/not; aggregates wrap a predicate (or stand alone, for distinct).
LEAF_KINDS = frozenset({"version", "record", "records", "range", "evolution",
                        "where", "where_range"})
COMPOSITE_KINDS = frozenset({"and", "or", "not"})
AGGREGATE_KINDS = frozenset({"count", "exists", "distinct"})
PREDICATE_KINDS = (LEAF_KINDS - {"evolution"}) | COMPOSITE_KINDS


# ------------------------------------------------------------------- algebra
@dataclass(frozen=True)
class Query:
    """One retrieval request — a node of the logical plan tree.  Build via
    the :class:`Q` factory."""

    kind: str          # version | record | records | range | evolution |
    #                    where | where_range | and | or | not |
    #                    count | exists | distinct
    vid: Optional[int] = None
    pk: Optional[int] = None
    pks: Optional[Tuple[int, ...]] = None
    key_lo: Optional[int] = None         # pk bound (range) / value bound (where_range)
    key_hi: Optional[int] = None
    attr: Optional[str] = None           # secondary-index attribute (where*, distinct)
    value: Optional[int] = None          # exact attribute value (where)
    children: Optional[Tuple["Query", ...]] = None   # and/or/not/count/exists


class Q:
    """Query constructors: the session API's algebra (§2.4 query classes,
    grown into a composable predicate/aggregate tree language)."""

    @staticmethod
    def version(vid: int) -> Query:
        """Q1: every record live in version ``vid`` → Dict[pk, bytes]."""
        return Query(kind="version", vid=int(vid))

    @staticmethod
    def record(vid: int, pk: int) -> Query:
        """Point lookup of ``pk`` in ``vid`` → Optional[bytes]."""
        return Query(kind="record", vid=int(vid), pk=int(pk))

    @staticmethod
    def records(vid: int, pks: Iterable[int]) -> Query:
        """Multi-point lookup in ``vid`` → Dict[pk, bytes] (absent keys
        omitted)."""
        return Query(kind="records", vid=int(vid),
                     pks=tuple(int(p) for p in pks))

    @staticmethod
    def range(vid: int, key_lo: int, key_hi: int) -> Query:
        """Q2: records of ``vid`` with pk in [key_lo, key_hi] → Dict."""
        return Query(kind="range", vid=int(vid), key_lo=int(key_lo),
                     key_hi=int(key_hi))

    @staticmethod
    def evolution(pk: int) -> Query:
        """Q3: every distinct record ever stored under ``pk`` →
        List[(origin_vid, bytes)] in origin order."""
        return Query(kind="evolution", pk=int(pk))

    @staticmethod
    def where(vid: int, attr: str, value: int) -> Query:
        """Filtered scan: records of ``vid`` whose extracted ``attr`` equals
        ``value`` → Dict[pk, bytes].  Needs a secondary index on ``attr``
        (``rs.create_index``); results are exact — lossy chunk-granularity
        postings are post-filtered per record."""
        return Query(kind="where", vid=int(vid), attr=str(attr),
                     value=int(value))

    @staticmethod
    def where_range(vid: int, attr: str, lo: int, hi: int) -> Query:
        """Filtered scan: records of ``vid`` with extracted ``attr`` in
        ``[lo, hi]`` → Dict[pk, bytes].  Same index + exactness contract as
        :meth:`where`."""
        return Query(kind="where_range", vid=int(vid), attr=str(attr),
                     key_lo=int(lo), key_hi=int(hi))

    # -------------------------------------------------- composite predicates
    @staticmethod
    def _check_predicate(q: Query, op: str) -> Query:
        if not isinstance(q, Query) or q.kind not in PREDICATE_KINDS:
            raise ValueError(
                f"Q.{op} composes predicate queries "
                f"(where/where_range/range/records/record/version or nested "
                f"and_/or_/not_); got "
                f"{q.kind if isinstance(q, Query) else type(q).__name__!r}")
        return q

    @staticmethod
    def _composite(op: str, queries: Tuple[Query, ...]) -> Query:
        if len(queries) < 2:
            raise ValueError(f"Q.{op}_ needs at least 2 sub-queries")
        vids = set()
        for q in queries:
            Q._check_predicate(q, f"{op}_")
            vids.add(q.vid)
        if len(vids) != 1:
            raise ValueError(
                f"Q.{op}_ sub-queries must share one version; got {sorted(vids)}")
        return Query(kind=op, vid=vids.pop(), children=tuple(queries))

    @staticmethod
    def and_(*queries: Query) -> Query:
        """Records of the shared version satisfying EVERY sub-predicate →
        Dict[pk, bytes]."""
        return Q._composite("and", queries)

    @staticmethod
    def or_(*queries: Query) -> Query:
        """Records of the shared version satisfying ANY sub-predicate →
        Dict[pk, bytes]."""
        return Q._composite("or", queries)

    @staticmethod
    def not_(query: Query) -> Query:
        """Records of the version NOT satisfying ``query`` → Dict[pk,
        bytes] (complement within the version's live records)."""
        Q._check_predicate(query, "not_")
        return Query(kind="not", vid=query.vid, children=(query,))

    # ------------------------------------------------------------ aggregates
    @staticmethod
    def count(query: Query) -> Query:
        """Number of records ``query`` would return → int.  Index-only or
        metadata-only: never fetches a chunk payload."""
        Q._check_predicate(query, "count")
        return Query(kind="count", vid=query.vid, children=(query,))

    @staticmethod
    def exists(query: Query) -> Query:
        """Does ``query`` match at least one record? → bool.  Same
        zero-payload execution as :meth:`count`."""
        Q._check_predicate(query, "exists")
        return Query(kind="exists", vid=query.vid, children=(query,))

    @staticmethod
    def distinct(vid: int, attr: str) -> Query:
        """Sorted distinct values of indexed ``attr`` over the records live
        in ``vid`` → List[int].  Answered from chunk maps + the index's
        per-record values: zero chunk-payload fetches."""
        return Query(kind="distinct", vid=int(vid), attr=str(attr))


# -------------------------------------------------------------------- results
@dataclass
class QueryStats:
    """Per-query (and, via :class:`BatchResult`, batch-level) fetch stats."""

    chunks_fetched: int = 0        # chunks touched (payloads and/or maps)
    irrelevant_chunks: int = 0     # lossy-projection artifacts (§2.4)
    bytes_fetched: int = 0
    kvs_queries: int = 0           # backend round trips
    records_returned: int = 0
    cache_hits: int = 0            # batch-level: keys a CachingKVS served
    bytes_from_cache: int = 0      # batch-level: payload served at memory speed
    payload_chunks_fetched: int = 0  # chunks whose payload blob was fetched
    payload_round_trips: int = 0   # round trips that carried payload keys
    #                                (0 for index-only/metadata plans)


@dataclass
class QueryResult:
    query: Query
    value: Any                     # Dict / Optional[bytes] / List / int / bool
    stats: QueryStats


class BatchResult(List[QueryResult]):
    """``Snapshot.execute``'s return: a List[QueryResult] carrying the
    batch-level stats.  ``batch.bytes_fetched`` counts every fetched chunk
    once, no matter how many queries shared it; per-query stats attribute a
    chunk to every query that planned it."""

    batch: QueryStats

    def __init__(self, results: Iterable[QueryResult], batch: QueryStats):
        super().__init__(results)
        self.batch = batch


# -------------------------------------------------------------- normalization
def normalize(q: Query) -> Query:
    """Structural simplification, semantics-preserving:

    - flatten nested same-op ``and``/``or`` nodes,
    - drop duplicate children (Query is frozen/hashable),
    - cancel double negation,
    - collapse single-child composites.
    """
    if q.kind in ("and", "or"):
        flat: List[Query] = []
        seen = set()
        for c in q.children:
            c = normalize(c)
            parts = c.children if c.kind == q.kind else (c,)
            for p in parts:
                if p not in seen:
                    seen.add(p)
                    flat.append(p)
        if len(flat) == 1:
            return flat[0]
        return Query(kind=q.kind, vid=q.vid, children=tuple(flat))
    if q.kind == "not":
        c = normalize(q.children[0])
        if c.kind == "not":
            return c.children[0]
        return Query(kind="not", vid=q.vid, children=(c,))
    if q.kind in ("count", "exists"):
        return Query(kind=q.kind, vid=q.vid,
                     children=(normalize(q.children[0]),))
    return q


def _walk(q: Query):
    yield q
    for c in q.children or ():
        yield from _walk(c)


# ------------------------------------------------------------- physical plans
@dataclass
class PlannedQuery:
    """One query's physical plan: its mode, candidate chunks, and whether
    those candidates need payload blobs or chunk maps only."""

    query: Query                   # normalized tree
    mode: str                      # "metadata" | "index_only" | "fetch"
    cand: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))

    @property
    def needs_payload(self) -> bool:
        return self.mode == "fetch"

    @property
    def needs_maps(self) -> bool:
        return self.mode in ("fetch", "index_only") and len(self.cand) > 0


# constant-folded compilation results (chunk-candidate lattice extremes)
_EMPTY = "EMPTY"        # provably no candidate chunks
_UNIVERSE = "UNIVERSE"  # no chunk-level restriction (≡ the version bitmap)


class Planner:
    """Compiles a batch of logical plans into physical plans with ONE fused
    bitmap-program launch for every query that needs index-ANDing."""

    def __init__(self, graph, proj: Projections,
                 indexes: Dict[str, Any], vidx: Dict[int, int]) -> None:
        self.graph = graph
        self.proj = proj
        self.indexes = indexes
        self.vidx = vidx
        # batch-wide leaf-row dedupe: identical predicates across queries
        # share one register row (the "duplicate-posting reuse" rule)
        self._rows: List[np.ndarray] = []
        self._row_of: Dict[Any, int] = {}
        self._prog: List[Tuple[int, int, int, int]] = []
        self._W = max((proj.n_chunks + 31) // 32, 1)

    # ------------------------------------------------------------ validation
    def _validate(self, q: Query) -> None:
        for node in _walk(q):
            if node.vid is not None and self.graph.is_retired(node.vid):
                raise KeyError(
                    f"version {node.vid} was retired by a retention policy; "
                    "its content is no longer queryable")
            if node.kind in ("where", "where_range", "distinct"):
                if self.indexes.get(node.attr) is None:
                    raise KeyError(
                        f"no secondary index on attribute {node.attr!r}; "
                        "register one with rs.create_index(attr, extractor)")
            if node.kind not in LEAF_KINDS | COMPOSITE_KINDS | AGGREGATE_KINDS:
                raise ValueError(f"unknown query kind {node.kind!r}")

    # ------------------------------------------------------------- leaf rows
    def _reg_of_row(self, key: Any, build: Callable[[], np.ndarray]) -> int:
        r = self._row_of.get(key)
        if r is None:
            r = len(self._rows)
            self._rows.append(build())
            self._row_of[key] = r
        return r

    def _version_reg(self, vid: int) -> int:
        return self._reg_of_row(
            ("ver", vid),
            lambda: self.proj._bitmap_of(self.proj.chunks_for_version(vid)))

    def _live_reg(self) -> int:
        """Union of every retained version's chunk list: chunks outside it
        hold only retired record copies (evolution's dead-chunk pruning)."""
        def build() -> np.ndarray:
            row = np.zeros(self._W, dtype=np.uint32)
            for ids in self.proj.version_chunks.values():
                if len(ids):
                    np.bitwise_or.at(row, ids // 32,
                                     np.uint32(1) << (ids % 32).astype(np.uint32))
            return row
        return self._reg_of_row(("live",), build)

    def _leaf_postings(self, q: Query) -> List[Optional[np.ndarray]]:
        if q.kind == "where":
            return [self.indexes[q.attr].postings_for(q.value)]
        if q.kind == "where_range":
            return self.indexes[q.attr].postings_in_range(q.key_lo, q.key_hi)
        if q.kind == "record":
            pks: Iterable[int] = [q.pk]
        elif q.kind == "records":
            pks = q.pks
        else:  # range
            pks = self.proj.keys_in_range(q.key_lo, q.key_hi)
        return [self.proj.key_chunks.get(int(p)) for p in pks]

    def _leaf_reg(self, q: Query) -> Union[str, int]:
        """Register of a leaf predicate's OR'd posting row, or ``_EMPTY``."""
        key = (q.kind, q.pk, q.pks, q.key_lo, q.key_hi, q.attr, q.value)
        if key in self._row_of:
            return self._row_of[key]
        postings = self._leaf_postings(q)
        if not any(p is not None and len(p) for p in postings):
            return _EMPTY
        row = np.zeros(self._W, dtype=np.uint32)
        for ids in postings:
            if ids is not None and len(ids):
                np.bitwise_or.at(row, ids // 32,
                                 np.uint32(1) << (ids % 32).astype(np.uint32))
        return self._reg_of_row(key, lambda: row)

    # ------------------------------------------------------- tree compilation
    def _emit(self, op: int, lhs: int, rhs: int) -> int:
        dst = -len(self._prog) - 1          # placeholder: patched after rows
        self._prog.append((op, dst, lhs, rhs))
        return dst

    def _compile(self, q: Query) -> Union[str, int]:
        """Compile a predicate tree to a register holding its candidate
        bitmap (chunk-granularity superset), or a lattice extreme.

        ``not_`` compiles to ``_UNIVERSE``: chunk-level complement of a
        record-level predicate is unsound (the chunk can hold non-matching
        live records), so its candidates are the whole version — exactness
        is restored by the per-record filter in the answer layer."""
        if q.kind == "version":
            return _UNIVERSE
        if q.kind == "not":
            return _UNIVERSE
        if q.kind in ("and", "or"):
            regs: List[int] = []
            for c in q.children:
                r = self._compile(c)
                if q.kind == "and":
                    if r is _EMPTY:
                        return _EMPTY
                    if r is _UNIVERSE:
                        continue            # no restriction to intersect
                else:
                    if r is _UNIVERSE:
                        return _UNIVERSE
                    if r is _EMPTY:
                        continue            # contributes nothing to the union
                regs.append(r)
            if not regs:
                return _UNIVERSE if q.kind == "and" else _EMPTY
            acc = regs[0]
            op = kbitmap.OP_AND if q.kind == "and" else kbitmap.OP_OR
            for r in regs[1:]:
                acc = self._emit(op, acc, r)
            return acc
        return self._leaf_reg(q)

    # ------------------------------------------------------------ batch plan
    def plan_batch(self, queries: Sequence[Query]) -> List[PlannedQuery]:
        """One-shot: compile the whole batch, run (at most) ONE fused
        bitmap-program launch, return the physical plans."""
        planned: List[PlannedQuery] = []
        # (position in `planned`, root register) per launch-dependent query
        pending_roots: List[Tuple[int, int]] = []
        for pos, q in enumerate(queries):
            q = normalize(q)
            self._validate(q)
            if q.kind in AGGREGATE_KINDS:
                pq = self._plan_aggregate(q, pending_roots, pos)
            elif q.kind == "evolution":
                pq = self._plan_evolution(q, pending_roots, pos)
            elif q.kind == "version":
                pq = PlannedQuery(q, "fetch",
                                  np.asarray(self.proj.chunks_for_version(q.vid)))
            else:
                pq = PlannedQuery(q, "fetch")
                self._root(q, pq, pending_roots, pos)
            planned.append(pq)
        self._run_program(planned, pending_roots)
        return planned

    def _root(self, tree: Query, pq: PlannedQuery,
              pending: List[Tuple[int, int]], pos: int) -> None:
        """Resolve a predicate tree's candidates: fold with the version
        bitmap, either statically or as the tree's final AND instruction."""
        r = self._compile(tree)
        if r is _EMPTY:
            pq.cand = np.empty(0, np.int64)
        elif r is _UNIVERSE:
            pq.cand = np.asarray(self.proj.chunks_for_version(tree.vid))
        else:
            root = self._emit(kbitmap.OP_AND, r, self._version_reg(tree.vid))
            pending.append((pos, root))

    def _plan_evolution(self, q: Query, pending: List[Tuple[int, int]],
                        pos: int) -> PlannedQuery:
        cand = self.proj.chunks_for_key(q.pk)
        if len(cand) and self.graph.has_retired():
            # retention: AND away chunks in no retained version's list —
            # they hold only dead copies and would be fetched for nothing
            pq = PlannedQuery(q, "fetch")
            key_reg = self._reg_of_row(("key", q.pk),
                                       lambda: self.proj._bitmap_of(cand))
            root = self._emit(kbitmap.OP_AND, key_reg, self._live_reg())
            pending.append((pos, root))
            return pq
        return PlannedQuery(q, "fetch", np.asarray(cand))

    def _plan_aggregate(self, q: Query, pending: List[Tuple[int, int]],
                        pos: int) -> PlannedQuery:
        if q.kind == "distinct":
            return PlannedQuery(q, "index_only",
                                np.asarray(self.proj.chunks_for_version(q.vid)))
        base = q.children[0]
        needs_index = any(n.kind in ("where", "where_range")
                          for n in _walk(base))
        if not needs_index:
            # pure primary-key predicate: version membership + record keys
            # answer it from the graph — zero KVS traffic of any kind
            return PlannedQuery(q, "metadata")
        pq = PlannedQuery(q, "index_only")
        self._root(base, pq, pending, pos)
        return pq

    def _run_program(self, planned: List[PlannedQuery],
                     pending: List[Tuple[int, int]]) -> None:
        if not self._prog:
            return
        L = len(self._rows)
        regs = np.zeros((L + len(self._prog), self._W), dtype=np.uint32)
        for i, row in enumerate(self._rows):
            regs[i] = row
        # patch placeholder dsts (emitted as -k-1 before L was known)
        prog = np.asarray(
            [(op, L - dst - 1, self._fix(lhs, L), self._fix(rhs, L))
             for op, dst, lhs, rhs in self._prog], dtype=np.int32)
        out, _ = kops.bitmap_vm_batch(regs, prog)
        for pos, root in pending:
            planned[pos].cand = _bitmap_to_ids(out[self._fix(root, L)],
                                               self.proj.n_chunks)

    @staticmethod
    def _fix(reg: int, n_leaf_rows: int) -> int:
        """Map a register handle to its row: leaf registers are direct
        indices; instruction outputs were emitted as ``-k-1`` placeholders
        and live after the leaf rows."""
        return reg if reg >= 0 else n_leaf_rows - reg - 1


# --------------------------------------------------------------- answer layer
@dataclass
class ExecContext:
    """Everything the answer layer needs from the fetch layer: the decoded
    chunk state plus shared per-chunk caches (payload decode and (chunk,
    version) membership each happen once per batch, however many queries
    share them)."""

    graph: Any
    vidx: Dict[int, int]
    indexes: Dict[str, Any]
    fetched: Dict[int, Tuple[Any, Any, int]]   # cid -> (chunk|None, cmap, nbytes)
    payloads: Callable[[int], Dict[int, bytes]]
    members: Callable[[int, int], np.ndarray]
    retained_bits: Optional[np.ndarray] = None


def _keys_mask(node: Query, keys: np.ndarray) -> np.ndarray:
    """Evaluate a primary-key-only predicate tree over an array of record
    keys (the metadata path — where-leaves never reach here)."""
    if node.kind == "version":
        return np.ones(len(keys), dtype=bool)
    if node.kind == "record":
        return keys == node.pk
    if node.kind == "records":
        return np.isin(keys, np.asarray(node.pks, dtype=np.int64))
    if node.kind == "range":
        return (keys >= node.key_lo) & (keys <= node.key_hi)
    if node.kind == "not":
        return ~_keys_mask(node.children[0], keys)
    masks = [_keys_mask(c, keys) for c in node.children]
    return (np.logical_and.reduce(masks) if node.kind == "and"
            else np.logical_or.reduce(masks))


def _predicate_mask(node: Query, cid: int, cmap, locs: np.ndarray,
                    ctx: ExecContext) -> np.ndarray:
    """Exact per-record predicate over the chunk-local rows ``locs`` (the
    records of ``cid`` live in the query's version).  ``where`` leaves read
    the secondary index's per-record value arrays — extracted from the same
    payloads at index-maintenance time, so this matches re-extraction
    bit-for-bit without touching the payload blob."""
    if node.kind in ("where", "where_range"):
        vals, present = ctx.indexes[node.attr].record_values(cid)
        v, p = vals[locs], present[locs]
        if node.kind == "where":
            return p & (v == node.value)
        return p & (v >= node.key_lo) & (v <= node.key_hi)
    if node.kind == "not":
        return ~_predicate_mask(node.children[0], cid, cmap, locs, ctx)
    if node.kind in ("and", "or"):
        masks = [_predicate_mask(c, cid, cmap, locs, ctx)
                 for c in node.children]
        return (np.logical_and.reduce(masks) if node.kind == "and"
                else np.logical_or.reduce(masks))
    return _keys_mask(node, cmap.cks[locs] >> 32)


def answer(pq: PlannedQuery, ctx: ExecContext, stats: QueryStats):
    """THE per-kind switch: materialize one planned query's value from the
    shared fetch state.  Every read path — ``Snapshot.execute``, the
    ``query.py`` shim, the serve engine — lands here."""
    q = pq.query

    # ---------------------------------------------------------- aggregates
    if q.kind in ("count", "exists"):
        if pq.mode == "metadata":
            rids = ctx.graph.members(q.vid)
            keys = ctx.graph.store.keys()[rids]
            n = int(_keys_mask(q.children[0], keys).sum())
        else:
            vidx = ctx.vidx[q.vid]
            n = 0
            for c in pq.cand:
                cid = int(c)
                cmap = ctx.fetched[cid][1]
                locs = ctx.members(cid, vidx)
                hits = (int(_predicate_mask(q.children[0], cid, cmap, locs,
                                            ctx).sum())
                        if len(locs) else 0)
                if hits == 0:
                    stats.irrelevant_chunks += 1
                n += hits
        stats.records_returned = n
        return n if q.kind == "count" else bool(n)

    if q.kind == "distinct":
        idx = ctx.indexes[q.attr]
        vidx = ctx.vidx[q.vid]
        out_vals: set = set()
        for c in pq.cand:
            cid = int(c)
            locs = ctx.members(cid, vidx)
            if len(locs) == 0:
                stats.irrelevant_chunks += 1
                continue
            vals, present = idx.record_values(cid)
            sel = vals[locs][present[locs]]
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            out_vals.update(int(v) for v in np.unique(sel))
        stats.records_returned = len(out_vals)
        return sorted(out_vals)

    # ------------------------------------------------------------ retrieval
    if q.kind == "version":
        out: Dict[int, bytes] = {}
        vidx = ctx.vidx[q.vid]
        for c in pq.cand:
            cid = int(c)
            cmap = ctx.fetched[cid][1]
            locs = ctx.members(cid, vidx)
            if len(locs) == 0:
                stats.irrelevant_chunks += 1
                continue
            pay = ctx.payloads(cid)
            for li in locs:
                pk, _ = unpack_ck(int(cmap.cks[li]))
                out[pk] = pay[int(li)]
        stats.records_returned = len(out)
        return out

    if q.kind in ("record", "records", "range"):
        vidx = ctx.vidx[q.vid]
        out = {}
        for c in pq.cand:
            cid = int(c)
            cmap = ctx.fetched[cid][1]
            locs = ctx.members(cid, vidx)
            keys = cmap.cks[locs] >> 32
            if q.kind == "record":
                sel = locs[keys == q.pk]
            elif q.kind == "records":
                sel = locs[np.isin(keys, np.asarray(q.pks, dtype=np.int64))]
            else:
                sel = locs[(keys >= q.key_lo) & (keys <= q.key_hi)]
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            pay = ctx.payloads(cid)
            for li in sel:
                pk, _ = unpack_ck(int(cmap.cks[li]))
                out[pk] = pay[int(li)]
        stats.records_returned = len(out)
        if q.kind == "record":
            return out.get(q.pk)
        return out

    if q.kind in ("where", "where_range", "and", "or", "not"):
        # exact post-filter: the lossy candidates only say a chunk *may*
        # hold a match — the predicate tree is re-evaluated per record
        # (attribute leaves via the index's record values, key leaves via
        # the chunk map) so lossiness never leaks
        vidx = ctx.vidx[q.vid]
        out = {}
        for c in pq.cand:
            cid = int(c)
            cmap = ctx.fetched[cid][1]
            locs = ctx.members(cid, vidx)
            sel = (locs[_predicate_mask(q, cid, cmap, locs, ctx)]
                   if len(locs) else locs)
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            pay = ctx.payloads(cid)
            for li in sel:
                pk, _ = unpack_ck(int(cmap.cks[li]))
                out[pk] = pay[int(li)]
        stats.records_returned = len(out)
        return out

    if q.kind == "evolution":
        evo: List[Tuple[int, bytes]] = []
        for c in pq.cand:
            cid = int(c)
            cmap = ctx.fetched[cid][1]
            sel = np.flatnonzero((cmap.cks >> 32) == q.pk)
            if ctx.retained_bits is not None and len(sel):
                w = min(cmap.bitmap.shape[1], len(ctx.retained_bits))
                alive = (cmap.bitmap[sel, :w]
                         & ctx.retained_bits[:w]).any(axis=1)
                sel = sel[alive]
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            pay = ctx.payloads(cid)
            for li in sel:
                _, origin = unpack_ck(int(cmap.cks[li]))
                evo.append((origin, pay[int(li)]))
        evo.sort(key=lambda t: ctx.vidx.get(t[0], 1 << 30))
        stats.records_returned = len(evo)
        return evo

    raise ValueError(f"unknown query kind {q.kind!r}")


# ------------------------------------------------------------------ rendering
def _label(q: Query) -> str:
    if q.kind == "version":
        return f"version v={q.vid}"
    if q.kind == "record":
        return f"record pk={q.pk} @v{q.vid}"
    if q.kind == "records":
        return f"records pks={list(q.pks)} @v{q.vid}"
    if q.kind == "range":
        return f"range pk∈[{q.key_lo}, {q.key_hi}] @v{q.vid}"
    if q.kind == "evolution":
        return f"evolution pk={q.pk}"
    if q.kind == "where":
        return f"where {q.attr} == {q.value} @v{q.vid}"
    if q.kind == "where_range":
        return f"where {q.attr} ∈ [{q.key_lo}, {q.key_hi}] @v{q.vid}"
    if q.kind == "distinct":
        return f"distinct({q.attr}) @v{q.vid}"
    return q.kind  # and | or | not | count | exists


def _render(q: Query) -> List[str]:
    lines = [_label(q)]
    kids = q.children or ()
    for i, c in enumerate(kids):
        sub = _render(c)
        last = i == len(kids) - 1
        lines.append(("└─ " if last else "├─ ") + sub[0])
        lines.extend(("   " if last else "│  ") + s for s in sub[1:])
    return lines


def render_plan(pq: PlannedQuery) -> str:
    """Human-readable plan tree for ``Snapshot.explain``."""
    head = f"[{pq.mode}] candidates={len(pq.cand)}"
    return "\n".join([head] + _render(pq.query))
