"""Online (batched) partitioning (§4).

New commits land in a *delta store* (a list of pending version ids — their
records/deltas are already in the version graph, just not yet chunked).  When
``batch_size`` versions accumulate, the batch is partitioned by an adapted
version of the configured algorithm restricted to the batch's *new* records:
previously chunked records are never re-partitioned (the paper defers
re-partitioning to future work).  Chunk maps of affected old chunks are
rebuilt from the in-memory index and rewritten once per batch — the paper's
"recreate from scratch instead of fetch+update" trick — and the whole
batch's writes (new chunks + rebuilt maps) are group-committed by the
caller in one ``multiput`` per backend shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .partition import ALGORITHMS
from .partition.base import ChunkPacker
from .types import Chunk, Partitioning
from .version_graph import VersionGraph

_VIRTUAL_ROOT = -1


def affected_old_chunks(batch_version_chunks: Sequence[np.ndarray],
                        first_new_chunk: int) -> np.ndarray:
    """Pre-existing chunks touched by the batch's versions (their chunk maps
    gained version-membership bits and must be rebuilt).  Takes the
    per-version chunk-id arrays the flush already computed for its
    projections — one vectorized unique instead of a per-version Python
    set union."""
    if not batch_version_chunks:
        return np.empty(0, dtype=np.int64)
    cs = np.unique(np.concatenate(list(batch_version_chunks)))
    return cs[(cs >= 0) & (cs < first_new_chunk)]


class _BatchView:
    """Duck-typed VersionGraph view: the batch's versions as a forest hanging
    off a virtual root, memberships restricted to not-yet-placed records."""

    def __init__(self, graph: VersionGraph, batch: Sequence[int],
                 new_rids: np.ndarray) -> None:
        self._graph = graph
        self._batch = list(batch)
        self._bset = set(batch)
        self._new = new_rids
        self.store = graph.store
        self.root = _VIRTUAL_ROOT

    def postorder(self) -> List[int]:
        # commit order is parents-before-children ⇒ reversed is a valid
        # children-first order; the virtual root comes last.
        return list(reversed(self._batch)) + [_VIRTUAL_ROOT]

    def tree_children(self, vid: int) -> List[int]:
        if vid == _VIRTUAL_ROOT:
            return [v for v in self._batch
                    if self._graph.tree_parent(v) not in self._bset]
        return [c for c in self._graph.tree_children(vid) if c in self._bset]

    def members(self, vid: int) -> np.ndarray:
        if vid == _VIRTUAL_ROOT:
            return np.empty(0, np.int64)
        # graph.members is empty for retired versions, so a compaction
        # rewrite spanning the whole (partially retired) tree just sees
        # nothing to preserve there
        return np.intersect1d(self._graph.members(vid), self._new,
                              assume_unique=True)

    def dfs_order(self) -> List[int]:
        out: List[int] = []
        stack = list(reversed(self.tree_children(_VIRTUAL_ROOT)))
        while stack:
            v = stack.pop()
            out.append(v)
            stack.extend(reversed(self.tree_children(v)))
        return out

    def bfs_order(self) -> List[int]:
        out: List[int] = []
        frontier = self.tree_children(_VIRTUAL_ROOT)
        while frontier:
            out.extend(frontier)
            frontier = [c for v in frontier for c in self.tree_children(v)]
        return out

    @property
    def tree_delta(self):
        return self._graph.tree_delta


def partition_batch(graph: VersionGraph, batch: Sequence[int],
                    placed: np.ndarray, algorithm: str, capacity: int,
                    chunk_id_base: int,
                    records: Optional[np.ndarray] = None,
                    **algo_kw) -> Partitioning:
    """Partition the batch's new records; chunk ids start at chunk_id_base.

    ``records`` overrides the delta-derived record set: the compaction path
    passes the live records of its candidate chunks here (with ``placed``
    masking everything else) and ``batch`` = every version, re-running the
    same restricted partitioner over the records being rewritten.
    """
    if records is not None:
        new = np.unique(np.asarray(records, dtype=np.int64))
    else:
        new_rids: List[np.ndarray] = []
        for v in batch:
            adds = graph.tree_delta[v].adds
            new_rids.append(adds[~placed[adds]])
        new = (np.unique(np.concatenate(new_rids)) if new_rids
               else np.empty(0, np.int64))

    if algorithm in ("depth_first", "breadth_first", "delta", "shingle"):
        # greedy/stream algorithms: place new records in traversal order
        packer = ChunkPacker(graph.store.sizes, capacity)
        view = _BatchView(graph, batch, new)
        order = view.dfs_order() if algorithm != "breadth_first" else view.bfs_order()
        if algorithm == "delta":
            order = list(batch)
        keys = graph.store.keys()
        for v in order:
            adds = graph.tree_delta[v].adds
            adds = adds[~placed[adds]]
            adds = adds[np.argsort(keys[adds], kind="stable")]
            for r in adds:
                if not packer.is_placed(int(r)):
                    packer.place(int(r))
        part = packer.finish(algorithm, merge_partial=(algorithm != "delta"))
    elif algorithm == "bottom_up":
        view = _BatchView(graph, batch, new)
        algo = ALGORITHMS["bottom_up"](**algo_kw)
        part = algo.partition(view, capacity)  # type: ignore[arg-type]
    else:
        raise ValueError(f"online mode unsupported for {algorithm}")

    # re-base chunk ids
    chunks = [Chunk(chunk_id_base + i, c.record_ids, c.nbytes)
              for i, c in enumerate(part.chunks)]
    r2c = part.record_to_chunk.copy()
    r2c[r2c >= 0] += chunk_id_base
    return Partitioning(chunks=chunks, record_to_chunk=r2c,
                        algorithm=f"online_{algorithm}")
