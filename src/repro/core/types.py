"""Core value types for the RStore layer.

The paper's data model (§2.1): the unit of storage is an immutable *record*
identified by a *composite key* ``<primary-key, version-id-of-origin>``.
Versions are identified by integer version-ids (the paper permits hashes; we
use ints for array-friendliness and keep a side table for symbolic names).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

VersionId = int
PrimaryKey = int

# Composite keys are packed into a single int64: high 32 bits = primary key,
# low 32 bits = origin version-id.  This gives every distinct record a global
# address (§2.1 "global address space") that is also a valid array element.
_KEY_BITS = 32
_KEY_MASK = (1 << _KEY_BITS) - 1
# keys/versions are capped at 2^31-1 so packed values stay positive int64
_MAX_PART = (1 << 31) - 1


def pack_ck(key: PrimaryKey, version: VersionId) -> int:
    """Pack a composite key into an int64 scalar."""
    if not (0 <= key <= _MAX_PART and 0 <= version <= _MAX_PART):
        raise ValueError(f"composite key out of range: ({key}, {version})")
    return (key << _KEY_BITS) | version


def unpack_ck(ck: int) -> Tuple[PrimaryKey, VersionId]:
    return (ck >> _KEY_BITS) & _KEY_MASK, ck & _KEY_MASK


def pack_ck_array(keys: np.ndarray, versions: np.ndarray) -> np.ndarray:
    return (keys.astype(np.int64) << _KEY_BITS) | versions.astype(np.int64)


def unpack_ck_array(cks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    cks = cks.astype(np.int64)
    return (cks >> _KEY_BITS).astype(np.int64), (cks & _KEY_MASK).astype(np.int64)


@dataclass(frozen=True)
class CompositeKey:
    """``<K, V>`` — primary key plus the version where this record originated."""

    key: PrimaryKey
    version: VersionId

    def packed(self) -> int:
        return pack_ck(self.key, self.version)

    @staticmethod
    def from_packed(ck: int) -> "CompositeKey":
        k, v = unpack_ck(ck)
        return CompositeKey(k, v)

    def __repr__(self) -> str:  # matches the paper's ⟨K, V⟩ notation
        return f"<K{self.key},V{self.version}>"


@dataclass
class Record:
    """An immutable record: composite key + opaque payload bytes."""

    ck: CompositeKey
    payload: bytes

    @property
    def size(self) -> int:
        return len(self.payload)


@dataclass
class Delta:
    """The set of changes from a parent version to a child version (§2.1).

    ``adds`` holds records *created* in the child (newly inserted primary keys
    and new record-versions of modified keys); their composite keys carry the
    child's version-id.  ``dels`` holds the composite keys (as stored in the
    parent) of records removed or superseded in the child.

    ``Delta`` is symmetric in the paper (Δij = Δji); we store the directed
    (parent→child) form and expose :meth:`reversed` for the other direction.
    Consistency (Ghandeharizadeh et al.): Δ+ ∩ Δ− = ∅ is checked on ingest.
    """

    adds: Dict[PrimaryKey, bytes] = field(default_factory=dict)
    dels: List[CompositeKey] = field(default_factory=list)

    def validate(self, child_version: VersionId) -> None:
        del_keys = {ck.key for ck in self.dels}
        # A modified key appears in both dels (old record) and adds (new
        # record) — that is fine; what must not happen is the *same composite
        # key* on both sides, which cannot occur since adds carry the child's
        # version id and dels carry ancestor ids.
        for ck in self.dels:
            if ck.version == child_version:
                raise ValueError(f"delta deletes a record it creates: {ck}")
        if len(del_keys) != len(self.dels):
            raise ValueError("delta deletes the same primary key twice")

    @property
    def num_changes(self) -> int:
        return len(self.adds) + len(self.dels)


@dataclass
class Chunk:
    """A fixed-size group of records — the backend KVS storage unit (§2.4)."""

    chunk_id: int
    record_ids: np.ndarray  # int64 indices into the RecordStore
    nbytes: int = 0

    def __len__(self) -> int:
        return len(self.record_ids)


@dataclass
class Partitioning:
    """Result of a partitioning algorithm: record → chunk assignment."""

    chunks: List[Chunk]
    record_to_chunk: np.ndarray  # int64[num_records], -1 if unassigned
    algorithm: str = ""

    @property
    def num_chunks(self) -> int:
        return len(self.chunks)

    def validate(self, record_sizes: np.ndarray, capacity: int, slack: float = 0.25) -> None:
        """Paper's fixed-chunk-size invariant: every chunk ≤ C·(1+slack); every
        record assigned to exactly one chunk."""
        seen = np.zeros(len(self.record_to_chunk), dtype=bool)
        for ch in self.chunks:
            if len(ch.record_ids) == 0:
                raise ValueError(f"empty chunk {ch.chunk_id}")
            size = int(record_sizes[ch.record_ids].sum())
            # single records larger than a chunk get a dedicated chunk
            if size > capacity * (1 + slack) and len(ch.record_ids) > 1:
                raise ValueError(
                    f"chunk {ch.chunk_id} overfull: {size} > {capacity * (1 + slack)}")
            if seen[ch.record_ids].any():
                raise ValueError("record assigned to multiple chunks")
            seen[ch.record_ids] = True
        if not seen.all():
            raise ValueError(f"{int((~seen).sum())} records unassigned")
