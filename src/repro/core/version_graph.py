"""Version graph machinery (§2.1, §2.5, §3.2 delta algebra).

Holds the directed version DAG, per-edge deltas, DAG→tree conversion (Fig. 4),
materialized version memberships, and the record↔version bipartite graph in
CSR form that the partitioners consume.

Records are referenced by dense integer *record ids* into a
:class:`RecordStore`; all hot paths are vectorized NumPy over sorted int64
arrays (the partitioners are offline host-side algorithms, exactly as in the
paper where they run on the application server).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .types import (CompositeKey, PrimaryKey, VersionId, pack_ck,
                    pack_ck_array, unpack_ck_array)


class RecordStore:
    """Registry of all distinct records (each stored once — dedupe by design)."""

    def __init__(self) -> None:
        self._cks: List[int] = []          # packed composite keys
        self._sizes: List[int] = []
        self._payloads: List[Optional[bytes]] = []
        self._index: Dict[int, int] = {}   # packed ck -> record id
        # array views are cached (invalidated on mutation): building them per
        # access is O(N) and turns per-record callers quadratic
        self._cks_arr: Optional[np.ndarray] = None
        self._sizes_arr: Optional[np.ndarray] = None
        self._keys_arr: Optional[np.ndarray] = None

    def _invalidate(self) -> None:
        self._cks_arr = None
        self._sizes_arr = None
        self._keys_arr = None

    def __len__(self) -> int:
        return len(self._cks)

    def add(self, ck: int, size: int, payload: Optional[bytes] = None) -> int:
        rid = self._index.get(ck)
        if rid is not None:
            raise ValueError(f"record {CompositeKey.from_packed(ck)} already exists")
        rid = len(self._cks)
        self._cks.append(ck)
        self._sizes.append(size)
        self._payloads.append(payload)
        self._index[ck] = rid
        self._invalidate()
        return rid

    def add_batch(self, cks: np.ndarray, sizes: np.ndarray,
                  payloads: Optional[Sequence[bytes]] = None) -> np.ndarray:
        base = len(self._cks)
        out = np.arange(base, base + len(cks), dtype=np.int64)
        self._cks.extend(int(c) for c in cks)
        self._sizes.extend(int(s) for s in sizes)
        if payloads is None:
            self._payloads.extend([None] * len(cks))
        else:
            self._payloads.extend(payloads)
        for i, c in enumerate(cks):
            c = int(c)
            if c in self._index:
                raise ValueError(f"record {CompositeKey.from_packed(c)} already exists")
            self._index[c] = base + i
        self._invalidate()
        return out

    def lookup(self, ck: int) -> Optional[int]:
        return self._index.get(ck)

    @property
    def cks(self) -> np.ndarray:
        if self._cks_arr is None or len(self._cks_arr) != len(self._cks):
            self._cks_arr = np.asarray(self._cks, dtype=np.int64)
        return self._cks_arr

    @property
    def sizes(self) -> np.ndarray:
        if self._sizes_arr is None or len(self._sizes_arr) != len(self._sizes):
            self._sizes_arr = np.asarray(self._sizes, dtype=np.int64)
        return self._sizes_arr

    def size_of(self, rid: int) -> int:
        return self._sizes[rid]

    def keys(self) -> np.ndarray:
        """Primary keys per record id (cached: this sits on the commit and
        flush hot paths, and unpacking is O(N))."""
        if self._keys_arr is None or len(self._keys_arr) != len(self._cks):
            self._keys_arr = unpack_ck_array(self.cks)[0]
        return self._keys_arr

    def origin_versions(self) -> np.ndarray:
        return unpack_ck_array(self.cks)[1]

    def payload(self, rid: int) -> bytes:
        p = self._payloads[rid]
        if p is None:
            raise KeyError(f"record {rid} has no payload stored")
        return p

    def has_payloads(self) -> bool:
        return len(self._payloads) > 0 and self._payloads[0] is not None

    def set_payload(self, rid: int, payload: bytes) -> None:
        self._payloads[rid] = payload
        self._sizes[rid] = len(payload)
        self._invalidate()


@dataclass
class DeltaIds:
    """Record-id level delta along a (parent → child) tree edge.

    ``adds``  — records present in child, absent in parent (Δ+).
    ``dels``  — records present in parent, absent in child (Δ−).
    Both are sorted int64 record-id arrays.  Reversing the edge swaps the two
    (the paper's Δij = Δji symmetry).
    """

    adds: np.ndarray
    dels: np.ndarray

    def reversed(self) -> "DeltaIds":
        return DeltaIds(adds=self.dels, dels=self.adds)

    def validate(self) -> None:
        if np.intersect1d(self.adds, self.dels).size:
            raise ValueError("inconsistent delta: Δ+ ∩ Δ− ≠ ∅")


class VersionGraph:
    """The version DAG + tree view + memberships.

    DAG→tree (Fig. 4): for a merge node we retain the edge to its *first*
    parent and drop the rest; records that arrived exclusively from dropped
    parents simply appear in the tree-delta's Δ+ of the merge node ("renamed
    to appear as newly inserted").  We keep the original record ids (the
    rename is bookkeeping — partitioners dedupe on first placement), and the
    original DAG remains available to queries afterwards, as in the paper.
    """

    def __init__(self, store: Optional[RecordStore] = None) -> None:
        self.store = RecordStore() if store is None else store
        self.parents: Dict[VersionId, Tuple[VersionId, ...]] = {}
        self.tree_delta: Dict[VersionId, DeltaIds] = {}   # keyed by child vid
        self._children: Dict[VersionId, List[VersionId]] = {}
        self.root: Optional[VersionId] = None
        self._memberships: Dict[VersionId, np.ndarray] = {}
        self._order: List[VersionId] = []                 # insertion (= topo) order
        # retention GC: retired versions keep their tree structure (stable
        # version indices for stored chunk-map bitmaps, ancestor walks) but
        # lose their membership — their content is logically deleted
        self._retired: set = set()

    # ------------------------------------------------------------- building
    def add_root(self, vid: VersionId, record_ids: np.ndarray) -> None:
        if self.root is not None:
            raise ValueError("root already set")
        self.root = vid
        self.parents[vid] = ()
        self._children[vid] = []
        record_ids = np.sort(np.asarray(record_ids, dtype=np.int64))
        self.tree_delta[vid] = DeltaIds(adds=record_ids, dels=np.empty(0, np.int64))
        self._memberships[vid] = record_ids
        self._order.append(vid)

    def add_version(self, vid: VersionId, parents: Sequence[VersionId],
                    adds: np.ndarray, dels: np.ndarray) -> None:
        """Add a version.  ``adds``/``dels`` are record ids relative to the
        *first* (retained) parent — callers with multi-parent merges must pass
        the delta vs. the retained parent (ingest.py computes this)."""
        if vid in self.parents:
            raise ValueError(f"version {vid} already exists")
        for p in parents:
            if p not in self.parents:
                raise ValueError(f"unknown parent version {p}")
        for p in parents:
            if p in self._retired:
                raise ValueError(
                    f"cannot commit onto retired version {p} (pruned by a "
                    "retention policy)")
        adds = np.sort(np.asarray(adds, dtype=np.int64))
        dels = np.sort(np.asarray(dels, dtype=np.int64))
        d = DeltaIds(adds=adds, dels=dels)
        d.validate()
        self.parents[vid] = tuple(parents)
        self._children[vid] = []
        for p in parents:
            self._children[p].append(vid)
        self.tree_delta[vid] = d
        parent_members = self.members(parents[0])
        if np.setdiff1d(dels, parent_members, assume_unique=False).size:
            raise ValueError("delta deletes records absent from parent")
        members = np.union1d(
            np.setdiff1d(parent_members, dels, assume_unique=True), adds)
        self._memberships[vid] = members
        self._order.append(vid)

    # ------------------------------------------------------------ structure
    @property
    def versions(self) -> List[VersionId]:
        return list(self._order)

    @property
    def num_versions(self) -> int:
        return len(self._order)

    def tree_parent(self, vid: VersionId) -> Optional[VersionId]:
        p = self.parents[vid]
        return p[0] if p else None

    def tree_children(self, vid: VersionId) -> List[VersionId]:
        """Children in the tree view (i.e. nodes whose retained parent is vid)."""
        return [c for c in self._children[vid] if self.parents[c][0] == vid]

    def dag_children(self, vid: VersionId) -> List[VersionId]:
        return list(self._children[vid])

    def is_merge(self, vid: VersionId) -> bool:
        return len(self.parents[vid]) > 1

    def depth(self, vid: VersionId) -> int:
        d = 0
        v: Optional[VersionId] = vid
        while v is not None and v != self.root:
            v = self.tree_parent(v)
            d += 1
        return d

    def path_to_root(self, vid: VersionId) -> List[VersionId]:
        path = [vid]
        v = vid
        while v != self.root:
            v = self.tree_parent(v)  # type: ignore[assignment]
            path.append(v)
        return path

    def leaves(self) -> List[VersionId]:
        return [v for v in self._order if not self.tree_children(v)]

    def avg_depth(self) -> float:
        ls = self.leaves()
        return float(np.mean([self.depth(v) for v in ls])) if ls else 0.0

    def dfs_order(self) -> List[VersionId]:
        """Pre-order DFS of the tree view, children in insertion order."""
        assert self.root is not None
        out: List[VersionId] = []
        stack = [self.root]
        while stack:
            v = stack.pop()
            out.append(v)
            stack.extend(reversed(self.tree_children(v)))
        return out

    def bfs_order(self) -> List[VersionId]:
        assert self.root is not None
        out: List[VersionId] = []
        frontier = [self.root]
        while frontier:
            out.extend(frontier)
            frontier = [c for v in frontier for c in self.tree_children(v)]
        return out

    def postorder(self) -> List[VersionId]:
        """Children-before-parent order of the tree view (bottom-up)."""
        return list(reversed(self.bfs_topdown_parents_first()))

    def bfs_topdown_parents_first(self) -> List[VersionId]:
        # insertion order is already parents-before-children
        return list(self._order)

    # ------------------------------------------------------------ retention
    def retire(self, vids: Sequence[VersionId]) -> None:
        """Logically delete ``vids`` (retention GC).

        The tree structure (parents, deltas, insertion order) survives so
        stored chunk-map bitmaps keep their version indices and ancestor
        walks still work; only the membership is dropped — the version's
        content becomes unreachable, and records reachable from no retained
        version are garbage that a compaction pass reclaims physically.
        """
        for v in vids:
            if v not in self.parents:
                raise ValueError(f"unknown version {v}")
        self._retired.update(vids)
        for v in vids:
            self._memberships.pop(v, None)

    def is_retired(self, vid: VersionId) -> bool:
        return vid in self._retired

    def has_retired(self) -> bool:
        return bool(self._retired)

    def retained_versions(self) -> List[VersionId]:
        """Non-retired versions in insertion order."""
        return [v for v in self._order if v not in self._retired]

    def live_record_mask(self) -> np.ndarray:
        """Bool mask over record ids: reachable from ≥1 retained version.
        With no retirement every membership record is live by definition."""
        mask = np.zeros(len(self.store), dtype=bool)
        for m in self._memberships.values():
            mask[m] = True
        return mask

    # ----------------------------------------------------------- membership
    def members(self, vid: VersionId) -> np.ndarray:
        """Sorted record ids constituting version ``vid``.  A retired
        version has no content: empty (partitioners treat it as carrying
        nothing to preserve; ingest/query paths guard explicitly)."""
        if vid in self._retired:
            return np.empty(0, dtype=np.int64)
        return self._memberships[vid]

    def memberships(self) -> Dict[VersionId, np.ndarray]:
        return dict(self._memberships)

    def version_sizes(self) -> Dict[VersionId, int]:
        sizes = self.store.sizes
        return {v: int(sizes[m].sum()) for v, m in self._memberships.items()}

    def total_entries(self) -> int:
        return sum(len(m) for m in self._memberships.values())

    # --------------------------------------------------- bipartite CSR view
    def record_version_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """Record→versions adjacency in CSR: (indptr[num_records+1], vids).

        Row r lists (sorted by version insertion index) the versions that
        contain record r.  This is the bipartite graph of §2.5 used by the
        shingle partitioner and the index builders.
        """
        n_rec = len(self.store)
        vidx = {v: i for i, v in enumerate(self._order)}
        rec_cat = np.concatenate([m for m in self._memberships.values()]) \
            if self._memberships else np.empty(0, np.int64)
        ver_cat = np.concatenate([
            np.full(len(m), vidx[v], dtype=np.int64)
            for v, m in self._memberships.items()]) \
            if self._memberships else np.empty(0, np.int64)
        order = np.lexsort((ver_cat, rec_cat))
        rec_sorted = rec_cat[order]
        ver_sorted = ver_cat[order]
        indptr = np.zeros(n_rec + 1, dtype=np.int64)
        counts = np.bincount(rec_sorted, minlength=n_rec)
        np.cumsum(counts, out=indptr[1:])
        # translate version indices back to version ids
        inv = np.asarray(self._order, dtype=np.int64)
        return indptr, inv[ver_sorted]

    def record_version_index_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """Like :meth:`record_version_csr` but with dense version *indices*."""
        indptr, vids = self.record_version_csr()
        vidx = {v: i for i, v in enumerate(self._order)}
        lut = np.zeros(max(self._order) + 1, dtype=np.int64)
        for v, i in vidx.items():
            lut[v] = i
        return indptr, lut[vids]

    # ------------------------------------------------------------ utilities
    def check_invariants(self) -> None:
        """Structural invariants used by property tests."""
        assert self.root is not None
        for v in self._order:
            if v in self._retired:
                assert v not in self._memberships
                continue
            m = self._memberships[v]
            assert (np.diff(m) > 0).all(), f"membership of {v} not sorted-unique"
            p = self.tree_parent(v)
            if p is None or p in self._retired:
                continue
            d = self.tree_delta[v]
            pm = self._memberships[p]
            # Δ+ disjoint from parent, Δ− subset of parent
            assert np.intersect1d(d.adds, pm).size == 0
            assert np.setdiff1d(d.dels, pm).size == 0
            recon = np.union1d(np.setdiff1d(pm, d.dels, assume_unique=True), d.adds)
            assert np.array_equal(recon, m)
            # every add carries this version as origin — except records pulled
            # in from dropped merge parents (Fig. 4), which keep their origin
            origins = self.store.origin_versions()[d.adds]
            if not self.is_merge(v):
                assert (origins == v).all(), f"adds of {v} carry wrong origin"
