"""RStore core: the paper's contribution — a multi-version document store
layered over a distributed key-value store."""
from .api import (BatchResult, Q, Query, QueryResult, QueryStats, Snapshot)
from .cache import CachingKVS
from .compact import (CompactionReport, Compactor, LayoutHealth,
                      RetentionPolicy, keep_all, keep_last, keep_tagged,
                      measure_layout)
from .datagen import PAPER_DATASETS, DatasetSpec, dataset_stats, generate
from .flusher import BackgroundFlusher, DrainReport
from .ingest import RStore, RStoreConfig, WriteSession
from .kvs import (Backend, InMemoryKVS, KVSStats, ShardedDeviceKVS,
                  ShardedKVS)
from .replica import (BackendTimeout, BackendUnavailable, FaultInjectingKVS,
                      QuorumLost, RecoveryManager, RecoveryReport,
                      ReplicatedKVS, RetryPolicy, ShardDown,
                      TransientBackendError)
from .secondary import (AttributeExtractor, SecondaryIndex,
                        datagen_extractor, struct_extractor)
from .types import Chunk, CompositeKey, Delta, Partitioning, Record
from .version_graph import DeltaIds, RecordStore, VersionGraph

__all__ = [
    "RStore", "RStoreConfig", "VersionGraph", "RecordStore", "DeltaIds",
    "CompositeKey", "Record", "Delta", "Chunk", "Partitioning",
    "DatasetSpec", "PAPER_DATASETS", "generate", "dataset_stats",
    "Q", "Query", "QueryResult", "QueryStats", "BatchResult", "Snapshot",
    "WriteSession", "BackgroundFlusher", "DrainReport",
    "Backend", "InMemoryKVS", "KVSStats", "ShardedKVS",
    "ShardedDeviceKVS", "CachingKVS",
    "Compactor", "CompactionReport", "LayoutHealth", "RetentionPolicy",
    "keep_all", "keep_last", "keep_tagged", "measure_layout",
    "BackendUnavailable", "TransientBackendError", "BackendTimeout",
    "ShardDown", "QuorumLost", "FaultInjectingKVS", "RetryPolicy",
    "ReplicatedKVS", "RecoveryManager", "RecoveryReport",
    "AttributeExtractor", "SecondaryIndex", "struct_extractor",
    "datagen_extractor",
]
