"""RStore facade: ingest (commit), build, flush, and query sessions (§2.4).

The user-facing API mirrors the paper's application server, with retrieval
redesigned around a plan/execute split (:mod:`repro.core.api`):

    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=1<<20, k=3))
    v0 = rs.init_root({pk: payload, ...})
    v1 = rs.commit([v0], adds={pk: new_payload}, dels=[pk2])   # delta ingest

    # Session API — the native path: a server collects a wave of queries,
    # the engine plans them together, dedupes candidate chunks across them,
    # and fetches chunks + maps in ONE KVS round trip.
    snap = rs.snapshot()                       # immutable read view
    res = snap.execute([Q.version(v1),
                        Q.record(v1, pk),
                        Q.range(v1, lo, hi),
                        Q.evolution(pk)])
    res[0].value, res[0].stats                 # per-query results/stats
    res.batch                                  # batch stats (1 round trip)

    # Back-compat wrappers — single-query sessions:
    records, stats = rs.get_version(v1)

Commits only carry the delta ("the system requests only those records from
the client that have changed").  Deltas accumulate in the delta store and are
chunked in batches (§4).  ``flush()`` is explicit; with the default
``RStoreConfig.auto_flush=True`` the facade keeps the seed behaviour of
flushing before a read, while ``auto_flush=False`` makes reads strictly
side-effect free (``snapshot()`` then refuses to observe unflushed deltas).
``build()`` runs the full offline pipeline (sub-chunking when k>1 →
partitioning → chunk/map writes → projections).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .chunkstore import build_chunk
from .index import Projections
from .kvs import KVS, InMemoryKVS
from .online import partition_batch
from .partition import ALGORITHMS, DeltaBaseline
from .api import BatchResult, Q, Snapshot
from .subchunk import (build_subchunks, build_transformed,
                       compressed_subchunk_sizes)
from .types import Chunk, Partitioning, pack_ck
from .version_graph import VersionGraph


@dataclass
class RStoreConfig:
    algorithm: str = "bottom_up"
    capacity: int = 1 << 16          # chunk size C in bytes
    k: int = 1                       # max records per sub-chunk (§3.4)
    batch_size: int = 64             # online batch (§4)
    beta: int = 64                   # BOTTOM-UP subtree bound (§3.2.1)
    shingle_hashes: int = 8
    store_payloads: bool = True
    auto_flush: bool = True          # seed behaviour: reads flush pending work

    def algo_kwargs(self) -> dict:
        if self.algorithm == "bottom_up":
            return {"beta": self.beta}
        if self.algorithm == "shingle":
            return {"n_hashes": self.shingle_hashes}
        return {}


class RStore:
    def __init__(self, config: Optional[RStoreConfig] = None,
                 kvs: Optional[KVS] = None) -> None:
        self.config = config or RStoreConfig()
        self.kvs: KVS = kvs if kvs is not None else InMemoryKVS()
        self.graph = VersionGraph()
        self._next_vid = 0
        self.pending: List[int] = []          # delta store (§4): unchunked vids
        self.r2c = np.empty(0, dtype=np.int64)  # record -> chunk (global)
        self.n_chunks = 0
        self.proj: Optional[Projections] = None
        self._subchunk_groups: Optional[List[np.ndarray]] = None
        self._flushed_versions = 0
        # bumped on every full build(): existing snapshots' chunk ids then
        # point at repartitioned storage, so they must fail loudly
        self._build_epoch = 0
        # chunk id -> record ids in *stored order* (chunk maps must preserve
        # the chunk's local record indexing when rebuilt)
        self._chunk_records: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------- ingest
    def _key_map(self, vid: int) -> Dict[int, int]:
        rids = self.graph.members(vid)
        keys = self.graph.store.keys()[rids]
        return dict(zip(keys.tolist(), rids.tolist()))

    def init_root(self, records: Dict[int, bytes]) -> int:
        vid = self._next_vid
        self._next_vid += 1
        cks = np.array([pack_ck(pk, vid) for pk in records], dtype=np.int64)
        sizes = np.array([len(p) for p in records.values()], dtype=np.int64)
        payloads = list(records.values()) if self.config.store_payloads else None
        rids = self.graph.store.add_batch(cks, sizes, payloads)
        self.graph.add_root(vid, rids)
        self._grow_r2c()
        self.pending.append(vid)
        self._maybe_flush()
        return vid

    def commit(self, parents: Sequence[int], adds: Dict[int, bytes],
               dels: Iterable[int] = ()) -> int:
        """Commit a new version as a delta from ``parents[0]`` (extra parents
        form a merge; their exclusive keys are pulled in per Fig. 4)."""
        vid = self._next_vid
        self._next_vid += 1
        pmap = self._key_map(parents[0])
        store = self.graph.store

        del_rids: List[int] = []
        dels = set(dels)
        for pk in dels:
            if pk not in pmap:
                raise KeyError(f"delete of absent key {pk}")
            del_rids.append(pmap[pk])

        add_rids: List[int] = []
        for pk, payload in adds.items():
            if pk in dels:
                raise ValueError(f"key {pk} both added and deleted")
            ck = pack_ck(pk, vid)
            rid = store.add(ck, len(payload),
                            payload if self.config.store_payloads else None)
            add_rids.append(rid)
            if pk in pmap:
                del_rids.append(pmap[pk])     # superseded record

        # merge parents: pull exclusive keys (Fig. 4 tree conversion)
        for other in parents[1:]:
            omap = self._key_map(other)
            for pk, rid in omap.items():
                if pk not in pmap and pk not in adds and pk not in dels:
                    add_rids.append(rid)

        self.graph.add_version(vid, list(parents), np.asarray(add_rids),
                               np.asarray(del_rids))
        self._grow_r2c()
        self.pending.append(vid)
        self._maybe_flush()
        return vid

    def _grow_r2c(self) -> None:
        n = len(self.graph.store)
        if n > len(self.r2c):
            grown = np.full(n, -1, dtype=np.int64)
            grown[:len(self.r2c)] = self.r2c
            self.r2c = grown

    # ------------------------------------------------------------ chunking
    def _maybe_flush(self) -> None:
        if len(self.pending) >= self.config.batch_size:
            self.flush()

    def flush(self) -> None:
        """Chunk the pending batch (§4 online path; k=1 only — the paper's
        online algorithm does not cover re-grouping sub-chunks)."""
        if not self.pending:
            return
        if self.config.k > 1:
            # compression mode: fall back to a full rebuild (documented)
            self.build()
            return
        batch = self.pending
        self.pending = []
        placed = self.r2c >= 0
        part = partition_batch(self.graph, batch, placed,
                               self.config.algorithm, self.config.capacity,
                               chunk_id_base=self.n_chunks,
                               **self.config.algo_kwargs())
        mask = part.record_to_chunk >= 0
        self.r2c[:len(mask)][mask] = part.record_to_chunk[mask]
        self.n_chunks += part.num_chunks

        # projections: new versions + affected old chunks
        if self.proj is None:
            self.proj = Projections(version_chunks={}, key_chunks={},
                                    n_chunks=self.n_chunks)
        self.proj.grow(self.n_chunks)
        keys = self.graph.store.keys()
        affected_old: set = set()
        for v in batch:
            vchunks = np.unique(self.r2c[self.graph.members(v)])
            assert (vchunks >= 0).all(), "unplaced record in flushed version"
            self.proj.extend_version(v, vchunks)
            old = vchunks[vchunks < self.n_chunks - part.num_chunks]
            affected_old.update(int(c) for c in old)
        kc: Dict[int, np.ndarray] = {}
        for c in part.chunks:
            for r in c.record_ids:
                kc.setdefault(int(keys[r]), []).append(c.chunk_id)  # type: ignore
        self.proj.extend_keys({pk: np.asarray(cs) for pk, cs in kc.items()})

        # write new chunks + rebuild affected old chunk maps (once per batch)
        csr = self.graph.record_version_index_csr()
        nv = self.graph.num_versions
        vidx_of = {v: i for i, v in enumerate(self.graph.versions)}
        for c in part.chunks:
            chunk, cmap = build_chunk(self.graph, c.record_ids, c.chunk_id,
                                      vidx_of, nv, csr)
            self._chunk_records[c.chunk_id] = c.record_ids
            self.kvs.put(f"chunk/{c.chunk_id}", chunk.to_bytes())
            self.kvs.put(f"map/{c.chunk_id}", cmap.to_bytes())
        for cid in affected_old:
            _, cmap = build_chunk(self.graph, self._chunk_records[cid], cid,
                                  vidx_of, nv, csr)
            self.kvs.put(f"map/{cid}", cmap.to_bytes())
        self._flushed_versions = self.graph.num_versions

    def build(self) -> Partitioning:
        """Full offline build (also the k>1 path)."""
        self._build_epoch += 1
        self.pending = []
        cfg = self.config
        graph = self.graph
        if cfg.k > 1:
            groups = build_subchunks(graph, cfg.k)
            sub_sizes = (compressed_subchunk_sizes(graph, groups)
                         if graph.store.has_payloads() else None)
            tds = build_transformed(graph, groups, sub_sizes)
            algo = ALGORITHMS[cfg.algorithm](**cfg.algo_kwargs())
            tpart = algo.partition(tds.tgraph, cfg.capacity)
            self._subchunk_groups = groups
            # compose record -> chunk
            self.r2c = tpart.record_to_chunk[tds.rec_to_sub]
            chunks = []
            for c in tpart.chunks:
                rec_ids = np.concatenate([groups[s] for s in c.record_ids])
                chunks.append(Chunk(c.chunk_id, np.sort(rec_ids), c.nbytes))
            part = Partitioning(chunks=chunks, record_to_chunk=self.r2c,
                                algorithm=f"{cfg.algorithm}_k{cfg.k}")
            sub_groups_of = {c.chunk_id: [groups[s] for s in tc.record_ids]
                             for c, tc in zip(chunks, tpart.chunks)}
        else:
            algo = ALGORITHMS[cfg.algorithm](**cfg.algo_kwargs())
            part = algo.partition(graph, cfg.capacity)
            self.r2c = part.record_to_chunk.copy()
            sub_groups_of = {}

        self.n_chunks = part.num_chunks
        self.proj = Projections.build_from_r2c(graph, self.r2c, self.n_chunks)

        csr = graph.record_version_index_csr()
        nv = graph.num_versions
        vidx_of = {v: i for i, v in enumerate(graph.versions)}
        self._chunk_records = {}
        for c in part.chunks:
            chunk, cmap = build_chunk(graph, c.record_ids, c.chunk_id, vidx_of,
                                      nv, csr,
                                      subchunk_groups=sub_groups_of.get(c.chunk_id))
            self._chunk_records[c.chunk_id] = c.record_ids
            self.kvs.put(f"chunk/{c.chunk_id}", chunk.to_bytes())
            self.kvs.put(f"map/{c.chunk_id}", cmap.to_bytes())
        self._flushed_versions = graph.num_versions
        return part

    # ------------------------------------------------------------- queries
    def snapshot(self) -> Snapshot:
        """Immutable read view of the flushed state (the session API).

        With ``auto_flush=True`` (seed behaviour) pending deltas are flushed
        first; with ``auto_flush=False`` reads are strictly side-effect free
        and unflushed deltas raise — call :meth:`flush` explicitly.
        """
        if self.pending:
            if self.config.auto_flush:
                self.flush()
            else:
                raise RuntimeError(
                    f"{len(self.pending)} unflushed version(s); call flush() "
                    "explicitly (auto_flush=False makes reads side-effect free)")
        assert self.proj is not None, "no data ingested"
        return Snapshot(self.graph, self.proj, self.kvs,
                        epoch=self._build_epoch,
                        current_epoch=lambda: self._build_epoch)

    def execute(self, queries) -> "BatchResult":
        """Run a batch of queries against a fresh snapshot (convenience)."""
        return self.snapshot().execute(queries)

    # Back-compat wrappers: each is a single-query session (one KVS round
    # trip; the seed paid two — chunks, then maps).
    def get_version(self, vid: int):
        r = self.snapshot().execute([Q.version(vid)])[0]
        return r.value, r.stats

    def get_record(self, vid: int, pk: int):
        r = self.snapshot().execute([Q.record(vid, pk)])[0]
        return r.value, r.stats

    def get_range(self, vid: int, key_lo: int, key_hi: int):
        r = self.snapshot().execute([Q.range(vid, key_lo, key_hi)])[0]
        return r.value, r.stats

    def get_evolution(self, pk: int):
        r = self.snapshot().execute([Q.evolution(pk)])[0]
        return r.value, r.stats

    # ------------------------------------------------------------- metrics
    def storage_stats(self) -> Dict[str, int]:
        """Chunk/index sizes.  Side-effect free on query counters: the sizing
        multiget is excluded from ``kvs.stats`` by save/restore instead of
        the seed's destructive ``reset()`` (which wiped whatever the caller
        was accumulating)."""
        saved = self.kvs.stats.snapshot()
        if self.n_chunks:
            blobs = self.kvs.multiget([f"chunk/{c}" for c in range(self.n_chunks)])
            stored = sum(len(b) for b in blobs)
        else:
            stored = 0
        self.kvs.stats.restore(saved)
        out = {
            "n_chunks": self.n_chunks,
            "stored_chunk_bytes": stored,
            "raw_unique_bytes": int(self.graph.store.sizes.sum()),
        }
        if self.proj is not None:
            out.update(self.proj.compressed_size())
        return out
