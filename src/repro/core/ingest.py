"""RStore facade: ingest (commit), build, flush, and query/write sessions
(§2.4).

The user-facing API mirrors the paper's application server, with *both*
directions redesigned around a plan/execute split: retrieval through
:mod:`repro.core.api`'s batched read sessions, and ingest through
group-committing write sessions:

    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=1<<20, k=3))

    # Write session — the native ingest path: stage a wave of commits,
    # flush once.  All new chunks and rebuilt chunk maps of the whole
    # session are committed via ONE multiput (one backend round trip per
    # shard under ShardedKVS).
    with rs.writer() as w:
        v0 = w.init_root({pk: payload, ...})
        v1 = w.commit([v0], adds={pk: new_payload}, dels=[pk2])
    # <- one group flush happened here

    # Back-compat wrappers — one-commit sessions that keep the seed's
    # delta-store batching (flush every `batch_size` versions):
    v2 = rs.commit([v1], adds={...})

    # Session reads (see api.py): plan a wave, fetch in one round trip/shard
    snap = rs.snapshot()
    res = snap.execute([Q.version(v1), Q.record(v1, pk), ...])

Commits only carry the delta ("the system requests only those records from
the client that have changed").  Deltas accumulate in the delta store and are
chunked in batches (§4); commit staging is columnar (one ``add_batch`` per
commit) and parent-key resolution uses cached sorted key arrays +
``searchsorted`` instead of rebuilding an O(|version|) Python dict per delta.
``flush()`` is explicit; with the default ``RStoreConfig.auto_flush=True``
the facade keeps the seed behaviour of flushing before a read, while
``auto_flush=False`` makes reads strictly side-effect free (``snapshot()``
then refuses to observe unflushed deltas).  ``build()`` runs the full offline
pipeline (sub-chunking when k>1 → partitioning → chunk/map writes →
projections).

With replicated shards (:class:`repro.core.replica.ReplicatedKVS`) the
group flush survives a replica death mid-workload unchanged: the one
``multiput`` per shard lands on every live replica with a write-ack quorum,
and replicas that missed it are backfilled by read-repair or a
:class:`repro.core.replica.RecoveryManager` rebuild.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .chunkstore import build_chunk
from .compact import CompactionReport, Compactor, RetentionPolicy
from .index import Projections
from .kvs import Backend, InMemoryKVS
from .online import affected_old_chunks, partition_batch
from .partition import ALGORITHMS
from .api import BatchResult, Q, Snapshot
from .secondary import AttributeExtractor, SecondaryIndex
from .subchunk import (build_subchunks, build_transformed,
                       compressed_subchunk_sizes)
from .types import _MAX_PART, Chunk, Partitioning, pack_ck_array
from .version_graph import VersionGraph


@dataclass
class RStoreConfig:
    algorithm: str = "bottom_up"
    capacity: int = 1 << 16          # chunk size C in bytes
    k: int = 1                       # max records per sub-chunk (§3.4)
    batch_size: int = 64             # online batch (§4)
    beta: int = 64                   # BOTTOM-UP subtree bound (§3.2.1)
    shingle_hashes: int = 8
    store_payloads: bool = True
    auto_flush: bool = True          # seed behaviour: reads flush pending work

    def algo_kwargs(self) -> dict:
        if self.algorithm == "bottom_up":
            return {"beta": self.beta}
        if self.algorithm == "shingle":
            return {"n_hashes": self.shingle_hashes}
        return {}


class WriteSession:
    """Staged ingest — the write-side mirror of :class:`~repro.core.api.Snapshot`.

    Obtained via :meth:`RStore.writer`.  ``init_root``/``commit`` stage
    versions in the delta store without flushing; ``close()`` (or context-
    manager exit) performs ONE group flush: the session's versions are
    chunked as a single batch and every new chunk + rebuilt chunk map is
    committed via a single ``multiput`` — one backend write round trip per
    shard under :class:`~repro.core.kvs.ShardedKVS`, O(shards) instead of
    the seed's ~2×n_chunks per-blob puts.

    Misuse is loud: only one session may be open per store (the facade
    wrappers count), and committing after ``close()`` raises.  If the
    ``with`` body raises, the flush is skipped — staged versions stay in
    the delta store and the next flush picks them up.

    With a :class:`~repro.core.flusher.BackgroundFlusher` attached
    (async ingest) the rules change: any number of sessions may be open
    concurrently, every ``commit()`` stages at zero round trips into the
    flusher's active buffer, and durability is the flusher's job
    (watermarks / ``rs.barrier()``) — ``close()`` does not flush, and an
    exception in the ``with`` body just closes the session (staged
    commits may already be durable; there is no per-session abort).
    """

    def __init__(self, rs: "RStore", flush_on_close: bool = True,
                 async_mode: bool = False) -> None:
        self._rs = rs
        self._flush_on_close = flush_on_close
        self._async = async_mode
        self._closed = False
        self.staged: List[int] = []        # vids committed through this session

    # ------------------------------------------------------------- staging
    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("WriteSession is closed; open a new writer()")

    def init_root(self, records: Dict[int, bytes]) -> int:
        self._check_open()
        vid = self._rs._stage_root(records)
        self.staged.append(vid)
        return vid

    def commit(self, parents: Sequence[int], adds: Dict[int, bytes],
               dels: Iterable[int] = ()) -> int:
        """Stage a new version as a delta from ``parents[0]`` (extra parents
        form a merge; their exclusive keys are pulled in per Fig. 4)."""
        self._check_open()
        vid = self._rs._stage_commit(parents, adds, dels)
        self.staged.append(vid)
        return vid

    # --------------------------------------------------------------- flush
    def flush(self) -> None:
        """Explicit early group flush of everything the store has staged.

        On a closed session, or with nothing staged, this is a cheap
        no-op — zero round trips, no stats noise (the empty-multiput
        convention).  In async mode it is a durability barrier
        (``rs.barrier()``); in sync mode it flushes the delta store
        mid-session (the staged-so-far versions become one group commit,
        the rest of the session a second one)."""
        if self._closed:
            return
        rs = self._rs
        if self._async:
            if rs._flusher is not None:
                rs._flusher.drain()
            return
        if not rs.pending:
            return
        # bypass the open-writer guard for this deliberate mid-session
        # flush; the guard exists to catch *implicit* splits of the
        # session's group commit, not an explicit request
        saved, rs._writer = rs._writer, None
        try:
            rs.flush()
        finally:
            rs._writer = saved

    def close(self) -> None:
        """Group-flush the session (idempotent).  Async sessions just
        deregister — drains belong to the flusher's watermarks."""
        if self._closed:
            return
        self._closed = True
        if self._async:
            self._rs._async_writers.discard(self)
            if self._rs._flusher is not None:
                self._rs._flusher.tick()   # close is a clock event
            return
        self._rs._writer = None
        if self._flush_on_close:
            self._rs.flush()
        else:
            self._rs._maybe_flush()

    def __enter__(self) -> "WriteSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None and not self._async:
            # abort: skip the flush, leave staged versions pending
            self._closed = True
            self._rs._writer = None
            return
        self.close()


class RStore:
    def __init__(self, config: Optional[RStoreConfig] = None,
                 kvs: Optional[Backend] = None) -> None:
        self.config = config or RStoreConfig()
        self.kvs: Backend = kvs if kvs is not None else InMemoryKVS()
        self.graph = VersionGraph()
        self._next_vid = 0
        self.pending: List[int] = []          # delta store (§4): unchunked vids
        self.r2c = np.empty(0, dtype=np.int64)  # record -> chunk (global)
        self.n_chunks = 0
        self.proj: Optional[Projections] = None
        self._subchunk_groups: Optional[List[np.ndarray]] = None
        self._flushed_versions = 0
        # bumped on every full build(): existing snapshots' chunk ids then
        # point at repartitioned storage, so they must fail loudly
        self._build_epoch = 0
        # bumped by every compaction pass: content is preserved, so open
        # snapshots re-pin via snapshot.refresh() instead of dying
        self._layout_epoch = 0
        # chunk id -> record ids in *stored order* (chunk maps must preserve
        # the chunk's local record indexing when rebuilt)
        self._chunk_records: Dict[int, np.ndarray] = {}
        # chunk id -> stored blob size, tracked at write time so
        # storage_stats() never has to fetch blobs just to size them
        self._chunk_bytes: Dict[int, int] = {}
        # version id -> (sorted primary keys, record ids in that order);
        # memberships are immutable once committed, so entries never go
        # stale (memory is bounded by total membership size, same order as
        # the graph's own materialized memberships)
        self._pk_arrays: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        # attr -> SecondaryIndex (see core/secondary.py); every mutation
        # path below keeps postings coherent inside its own round trips
        self._indexes: Dict[str, SecondaryIndex] = {}
        self._writer: Optional[WriteSession] = None
        # async ingest (core/flusher.py): when attached, any number of
        # sessions may stage concurrently and the flusher owns durability
        self._flusher = None
        self._async_writers: set = set()

    # ------------------------------------------------------------- sessions
    def writer(self, flush_on_close: bool = True) -> WriteSession:
        """Open a :class:`WriteSession`.  With the default
        ``flush_on_close=True`` the session group-flushes everything it
        staged on close; ``flush_on_close=False`` keeps the delta-store
        batching (flush only once ``batch_size`` versions accumulated) —
        the facade wrappers use that to preserve the seed behaviour.

        With a :class:`~repro.core.flusher.BackgroundFlusher` attached,
        sessions are concurrent: commits stage at zero round trips and
        drain together on the flusher's watermarks (``flush_on_close``
        is moot — close never flushes in async mode)."""
        if self._flusher is not None:
            ws = WriteSession(self, flush_on_close=False, async_mode=True)
            self._async_writers.add(ws)
            return ws
        if self._writer is not None and not self._writer._closed:
            raise RuntimeError(
                "another WriteSession is already open on this store; close "
                "it first (one writer per store — commits are serialized)")
        ws = WriteSession(self, flush_on_close=flush_on_close)
        self._writer = ws
        return ws

    # --------------------------------------------------------- async ingest
    @property
    def flusher(self):
        """The attached :class:`~repro.core.flusher.BackgroundFlusher`,
        or ``None`` (synchronous ingest)."""
        return self._flusher

    def attach_flusher(self, **flusher_kw):
        """Switch to async ingest: attach a
        :class:`~repro.core.flusher.BackgroundFlusher` (kwargs:
        ``max_staged_versions`` / ``max_staged_bytes`` /
        ``max_staged_age`` / ``retry``).  Versions already pending in the
        delta store are adopted into the active buffer.  Raises if a
        flusher is already attached or a sync WriteSession is open.
        Detach with ``flusher.close()`` (drains first)."""
        from .flusher import BackgroundFlusher
        if self._flusher is not None:
            raise RuntimeError("a BackgroundFlusher is already attached")
        if self._writer is not None and not self._writer._closed:
            raise RuntimeError(
                "close the open WriteSession before attaching a "
                "BackgroundFlusher (its group commit must not be split)")
        self._flusher = BackgroundFlusher(self, **flusher_kw)
        return self._flusher

    def barrier(self):
        """Durability barrier: everything committed before the call is
        durable when it returns.  With a flusher attached this drains
        both buffers (returns the :class:`~repro.core.flusher.DrainReport`);
        without one it flushes the delta store.  With nothing staged it
        is a cheap no-op — zero round trips, no stats noise."""
        if self._flusher is not None:
            return self._flusher.drain()
        if self.pending:
            self._check_no_open_writer("barrier()")
            self.flush()
        return None

    # ------------------------------------------------------------- ingest
    def _parent_key_arrays(self, vid: int) -> Tuple[np.ndarray, np.ndarray]:
        """(sorted primary keys, record ids aligned) of ``vid``'s live set —
        the searchsorted-friendly replacement for the seed's per-commit
        O(|version|) dict rebuild.  Cached per version (immutable)."""
        hit = self._pk_arrays.get(vid)
        if hit is None:
            rids = self.graph.members(vid)
            keys = self.graph.store.keys()[rids]
            order = np.argsort(keys, kind="stable")
            hit = (keys[order], rids[order])
            self._pk_arrays[vid] = hit
        return hit

    def _key_map(self, vid: int) -> Dict[int, int]:
        """pk -> record id of ``vid``'s live set (back-compat; hot paths use
        :meth:`_parent_key_arrays` directly)."""
        skeys, srids = self._parent_key_arrays(vid)
        return dict(zip(skeys.tolist(), srids.tolist()))

    @staticmethod
    def _find_in_sorted(sorted_keys: np.ndarray, pks: np.ndarray) -> np.ndarray:
        """Positions of ``pks`` in ``sorted_keys`` (-1 where absent)."""
        if len(pks) == 0:
            return np.empty(0, dtype=np.int64)
        pos = np.searchsorted(sorted_keys, pks)
        out = np.full(len(pks), -1, dtype=np.int64)
        in_range = pos < len(sorted_keys)
        hit = np.zeros(len(pks), dtype=bool)
        hit[in_range] = sorted_keys[pos[in_range]] == pks[in_range]
        out[hit] = pos[hit]
        return out

    @staticmethod
    def _check_pk_range(pks: np.ndarray, vid: int) -> None:
        if len(pks) and (int(pks.min()) < 0 or int(pks.max()) > _MAX_PART):
            bad = int(pks.min()) if int(pks.min()) < 0 else int(pks.max())
            raise ValueError(f"composite key out of range: ({bad}, {vid})")

    def _stage_root(self, records: Dict[int, bytes]) -> int:
        vid = self._next_vid
        self._next_vid += 1
        pks = np.fromiter(records.keys(), dtype=np.int64, count=len(records))
        self._check_pk_range(pks, vid)
        cks = pack_ck_array(pks, np.full(len(pks), vid, dtype=np.int64))
        sizes = np.fromiter((len(p) for p in records.values()),
                            dtype=np.int64, count=len(records))
        payloads = list(records.values()) if self.config.store_payloads else None
        rids = self.graph.store.add_batch(cks, sizes, payloads)
        self.graph.add_root(vid, rids)
        self._grow_r2c()
        self.pending.append(vid)
        if self._flusher is not None:
            self._flusher.on_stage(vid, int(sizes.sum()))
        return vid

    def _stage_commit(self, parents: Sequence[int], adds: Dict[int, bytes],
                      dels: Iterable[int] = ()) -> int:
        vid = self._next_vid
        self._next_vid += 1
        store = self.graph.store
        skeys, srids = self._parent_key_arrays(parents[0])

        dels = set(dels)
        del_pks = np.fromiter(dels, dtype=np.int64, count=len(dels))
        pos = self._find_in_sorted(skeys, del_pks)
        if (pos < 0).any():
            missing = int(del_pks[int(np.flatnonzero(pos < 0)[0])])
            raise KeyError(f"delete of absent key {missing}")
        del_rid_parts: List[np.ndarray] = [srids[pos]]

        both = dels.intersection(adds)
        if both:
            raise ValueError(f"key {next(iter(both))} both added and deleted")

        add_pks = np.fromiter(adds.keys(), dtype=np.int64, count=len(adds))
        self._check_pk_range(add_pks, vid)
        cks = pack_ck_array(add_pks, np.full(len(add_pks), vid, dtype=np.int64))
        sizes = np.fromiter((len(p) for p in adds.values()),
                            dtype=np.int64, count=len(adds))
        payloads = (list(adds.values())
                    if self.config.store_payloads else None)
        add_rid_parts: List[np.ndarray] = [store.add_batch(cks, sizes, payloads)]
        superseded = self._find_in_sorted(skeys, add_pks)
        del_rid_parts.append(srids[superseded[superseded >= 0]])

        # merge parents: pull exclusive keys (Fig. 4 tree conversion).
        # Earlier merge parents win: a key exclusive to two later parents is
        # pulled once (the seed silently admitted duplicate live records for
        # the same pk, leaving phantom records that dels could not remove).
        pulled_pks = np.empty(0, dtype=np.int64)
        for other in parents[1:]:
            okeys, orids = self._parent_key_arrays(other)
            pull = self._find_in_sorted(skeys, okeys) < 0
            if len(add_pks):
                pull &= ~np.isin(okeys, add_pks)
            if len(del_pks):
                pull &= ~np.isin(okeys, del_pks)
            if len(pulled_pks):
                pull &= ~np.isin(okeys, pulled_pks)
            add_rid_parts.append(orids[pull])
            pulled_pks = np.concatenate([pulled_pks, okeys[pull]])

        self.graph.add_version(vid, list(parents),
                               np.concatenate(add_rid_parts),
                               np.concatenate(del_rid_parts))
        self._grow_r2c()
        self.pending.append(vid)
        if self._flusher is not None:
            self._flusher.on_stage(vid, int(sizes.sum()))
        return vid

    # Back-compat wrappers: each is a one-commit write session that keeps
    # the seed's delta-store batching (flush at batch_size, not per commit).
    def init_root(self, records: Dict[int, bytes]) -> int:
        with self.writer(flush_on_close=False) as w:
            return w.init_root(records)

    def commit(self, parents: Sequence[int], adds: Dict[int, bytes],
               dels: Iterable[int] = ()) -> int:
        """Commit a new version as a delta from ``parents[0]`` (extra parents
        form a merge; their exclusive keys are pulled in per Fig. 4)."""
        with self.writer(flush_on_close=False) as w:
            return w.commit(parents, adds, dels)

    def _grow_r2c(self) -> None:
        n = len(self.graph.store)
        if n > len(self.r2c):
            grown = np.full(n, -1, dtype=np.int64)
            grown[:len(self.r2c)] = self.r2c
            self.r2c = grown

    # ------------------------------------------------------------ chunking
    def _check_no_open_writer(self, what: str) -> None:
        """Misuse is loud: chunking mid-session would split the open
        session's one group commit into several multiputs.  close() clears
        the writer slot before its own flush, so session closes pass.
        Async mode has no per-session group commit to protect — drains
        batch across open sessions by design, so the guard is moot."""
        if self._flusher is not None:
            return
        if self._writer is not None and not self._writer._closed:
            raise RuntimeError(
                f"{what} during an open WriteSession would split its group "
                "commit; close the session instead")

    def _maybe_flush(self) -> None:
        if self._flusher is not None:
            return                    # watermarks own the drain schedule
        if self._writer is not None and not self._writer._closed:
            return                    # an open session group-flushes on close
        if len(self.pending) >= self.config.batch_size:
            self.flush()

    def _stage_chunk_writes(self, chunks, vidx_of: Dict[int, int], nv: int,
                            csr, sub_groups_of: Optional[Dict] = None,
                            ) -> List[Tuple[str, bytes]]:
        """Build the physical blobs for ``chunks``, record them in the
        chunk bookkeeping, and return the staged ``(key, blob)`` write list
        — shared by flush(), build(), and the compactor so the key layout
        and size accounting can never diverge between the three paths."""
        writes: List[Tuple[str, bytes]] = []
        for c in chunks:
            chunk, cmap = build_chunk(
                self.graph, c.record_ids, c.chunk_id, vidx_of, nv, csr,
                subchunk_groups=(sub_groups_of or {}).get(c.chunk_id))
            self._chunk_records[c.chunk_id] = c.record_ids
            blob = chunk.to_bytes()
            self._chunk_bytes[c.chunk_id] = len(blob)
            writes.append((f"chunk/{c.chunk_id}", blob))
            writes.append((f"map/{c.chunk_id}", cmap.to_bytes()))
        return writes

    def flush(self) -> None:
        """Chunk the pending batch (§4 online path; k=1 only — the paper's
        online algorithm does not cover re-grouping sub-chunks) and commit
        every new chunk + rebuilt map in ONE ``multiput`` (the group
        commit: one backend write round trip per shard).  With a
        :class:`~repro.core.flusher.BackgroundFlusher` attached this is a
        drain barrier instead (same durability, flusher bookkeeping)."""
        if self._flusher is not None:
            self._flusher.drain()
            return
        self._check_no_open_writer("flush()")
        if not self.pending:
            return
        if self.config.k > 1:
            # compression mode: fall back to a full rebuild (documented)
            self.build()
            return
        batch = self.pending
        self.pending = []
        writes = self._prepare_flush_writes(batch)
        self.kvs.multiput(writes)
        self._flushed_versions = self.graph.num_versions

    def _prepare_flush_writes(self, batch: List[int]) -> List[Tuple[str, bytes]]:
        """Online-chunk ``batch`` and stage its physical writes — new
        chunks, rebuilt old chunk maps, extended index postings — WITHOUT
        touching the backend.  All in-memory layout state (r2c, proj,
        chunk bookkeeping) is advanced here; the caller owns the one
        ``multiput`` that makes it durable (flush() immediately, the
        BackgroundFlusher on its own drain schedule)."""
        placed = self.r2c >= 0
        part = partition_batch(self.graph, batch, placed,
                               self.config.algorithm, self.config.capacity,
                               chunk_id_base=self.n_chunks,
                               **self.config.algo_kwargs())
        mask = part.record_to_chunk >= 0
        self.r2c[:len(mask)][mask] = part.record_to_chunk[mask]
        first_new = self.n_chunks
        self.n_chunks += part.num_chunks

        # projections: new versions + affected old chunks
        if self.proj is None:
            self.proj = Projections(version_chunks={}, key_chunks={},
                                    n_chunks=self.n_chunks)
        self.proj.grow(self.n_chunks)
        keys = self.graph.store.keys()
        batch_vchunks: List[np.ndarray] = []
        for v in batch:
            vchunks = np.unique(self.r2c[self.graph.members(v)])
            assert (vchunks >= 0).all(), "unplaced record in flushed version"
            self.proj.extend_version(v, vchunks)
            batch_vchunks.append(vchunks)
        affected_old = affected_old_chunks(batch_vchunks, first_new)
        kc: Dict[int, np.ndarray] = {}
        for c in part.chunks:
            for r in c.record_ids:
                kc.setdefault(int(keys[r]), []).append(c.chunk_id)  # type: ignore
        self.proj.extend_keys({pk: np.asarray(cs) for pk, cs in kc.items()})

        # stage new chunks + rebuilt old chunk maps, commit in ONE multiput
        csr = self.graph.record_version_index_csr()
        nv = self.graph.num_versions
        vidx_of = {v: i for i, v in enumerate(self.graph.versions)}
        writes = self._stage_chunk_writes(part.chunks, vidx_of, nv, csr)
        for cid in affected_old:
            cid = int(cid)
            _, cmap = build_chunk(self.graph, self._chunk_records[cid], cid,
                                  vidx_of, nv, csr)
            writes.append((f"map/{cid}", cmap.to_bytes()))
        # secondary indexes: extend postings for the batch's new chunks —
        # dirty idx2/ buckets ride the same group commit
        if self._indexes:
            new_chunks = [(c.chunk_id, c.record_ids) for c in part.chunks]
            for idx in self._indexes.values():
                idx.add_chunks(new_chunks, self.graph.store.payload)
                iw, idel = idx.stage_writes()
                writes.extend(iw)
                assert not idel, "appending chunks never empties a bucket"
        return writes

    def build(self) -> Partitioning:
        """Full offline build (also the k>1 path)."""
        self._check_no_open_writer("build()")
        if self._flusher is not None:
            # drain barrier: staged work lands in the OLD layout first, so
            # a replay from a failed drain can never cross the rebuild and
            # resurrect superseded keys (a failed drain aborts the build)
            self._flusher.drain()
        self._build_epoch += 1
        self.pending = []
        cfg = self.config
        graph = self.graph
        if cfg.k > 1:
            groups = build_subchunks(graph, cfg.k)
            sub_sizes = (compressed_subchunk_sizes(graph, groups)
                         if graph.store.has_payloads() else None)
            tds = build_transformed(graph, groups, sub_sizes)
            algo = ALGORITHMS[cfg.algorithm](**cfg.algo_kwargs())
            tpart = algo.partition(tds.tgraph, cfg.capacity)
            self._subchunk_groups = groups
            # compose record -> chunk
            self.r2c = tpart.record_to_chunk[tds.rec_to_sub]
            chunks = []
            for c in tpart.chunks:
                rec_ids = np.concatenate([groups[s] for s in c.record_ids])
                chunks.append(Chunk(c.chunk_id, np.sort(rec_ids), c.nbytes))
            part = Partitioning(chunks=chunks, record_to_chunk=self.r2c,
                                algorithm=f"{cfg.algorithm}_k{cfg.k}")
            sub_groups_of = {c.chunk_id: [groups[s] for s in tc.record_ids]
                             for c, tc in zip(chunks, tpart.chunks)}
        else:
            algo = ALGORITHMS[cfg.algorithm](**cfg.algo_kwargs())
            part = algo.partition(graph, cfg.capacity)
            self.r2c = part.record_to_chunk.copy()
            sub_groups_of = {}

        self.n_chunks = part.num_chunks
        self.proj = Projections.build_from_r2c(graph, self.r2c, self.n_chunks)

        csr = graph.record_version_index_csr()
        nv = graph.num_versions
        vidx_of = {v: i for i, v in enumerate(graph.versions)}
        old_ids = set(self._chunk_records)
        self._chunk_records = {}
        self._chunk_bytes = {}
        writes = self._stage_chunk_writes(part.chunks, vidx_of, nv, csr,
                                          sub_groups_of)
        # GC: chunk ids of the previous layout that the rebuild did not
        # reuse would otherwise stay in the KVS forever (a rebuild can
        # shrink the chunk count — especially after retention pruning)
        stale = sorted(old_ids - set(self._chunk_records))
        stale_keys = [k for c in stale for k in (f"chunk/{c}", f"map/{c}")]
        # secondary indexes: recompute postings over the new layout inside
        # the same group commit; buckets that emptied out (all their values
        # lived only in retired versions) join the stale-key GC
        for idx in self._indexes.values():
            idx.rebuild(self._chunk_records, graph.store.payload)
            iw, idel = idx.stage_writes()
            writes.extend(iw)
            stale_keys.extend(idel)
        self.kvs.multiput(writes)      # one group commit, even for rebuilds
        self.kvs.multidelete(stale_keys)
        self._notify_layout_change(stale_keys)
        self._flushed_versions = graph.num_versions
        return part

    # -------------------------------------------------- retention/compaction
    def retain(self, policy: RetentionPolicy) -> List[int]:
        """Apply a retention policy: versions outside it are *retired* —
        pruned from the version graph and the version→chunks projection, so
        queries against them fail loudly.  Their record copies stay in
        storage as garbage until the next :meth:`compact` pass physically
        reclaims them.  Returns the newly retired version ids.
        """
        self._check_no_open_writer("retain()")
        if self._flusher is not None:
            # drain barrier — even with nothing pending a failed drain may
            # hold prepared writes whose replay must land before retirement
            self._flusher.drain()
        elif self.pending:
            if self.config.auto_flush:
                self.flush()
            else:
                raise RuntimeError(
                    f"{len(self.pending)} unflushed version(s); retention "
                    "works on the flushed graph — call flush() first")
        retained = set(policy.resolve(self.graph))
        to_retire = [v for v in self.graph.retained_versions()
                     if v not in retained]
        if not to_retire:
            return []
        self.graph.retire(to_retire)
        if self.proj is not None:
            self.proj.drop_versions(to_retire)
        for v in to_retire:
            self._pk_arrays.pop(v, None)
        return to_retire

    def compact(self, **compactor_kw) -> CompactionReport:
        """Run one background compaction pass (see
        :class:`~repro.core.compact.Compactor`): rewrite fragmented /
        low-liveness chunks through the configured partition algorithm in
        ONE group commit and GC the superseded keys in ONE ``multidelete``
        — each one backend round trip per shard touched.  Bumps the layout
        epoch; open snapshots re-pin with ``snapshot.refresh()``.

        Exception: with ``k > 1`` (sub-chunk compression) the pass falls
        back to a retention-aware full :meth:`build` — the online algorithm
        cannot re-group sub-chunks — which, like every rebuild, *hard*
        invalidates open snapshots (``refresh()`` raises; take a new
        ``snapshot()``)."""
        return Compactor(self, **compactor_kw).run_pass()

    @property
    def layout_epoch(self) -> int:
        return self._layout_epoch

    # --------------------------------------------------- secondary indexes
    def create_index(self, attr: str, extractor: AttributeExtractor,
                     n_buckets: int = 16) -> SecondaryIndex:
        """Register a secondary index on ``attr`` (see
        :mod:`repro.core.secondary`).  Existing chunks are indexed now (one
        ``multiput`` of the ``idx2/{attr}/*`` buckets); every later flush /
        build / compaction keeps the postings coherent inside its own round
        trips.  Enables ``Q.where(vid, attr, value)`` and
        ``Q.where_range(vid, attr, lo, hi)`` on snapshots."""
        if attr in self._indexes:
            raise ValueError(f"secondary index on {attr!r} already exists")
        if not self.config.store_payloads:
            raise RuntimeError(
                "secondary indexes need store_payloads=True — attribute "
                "extraction reads record payloads")
        idx = SecondaryIndex(attr, extractor, n_buckets=n_buckets)
        if self._chunk_records:
            idx.add_chunks(sorted(self._chunk_records.items()),
                           self.graph.store.payload)
            writes, _ = idx.stage_writes()
            self.kvs.multiput(writes)
        self._indexes[attr] = idx
        return idx

    def drop_index(self, attr: str) -> None:
        """Unregister the index on ``attr`` and GC its ``idx2/`` keys (one
        ``multidelete``).  Raises ``KeyError`` if no such index exists."""
        idx = self._indexes.pop(attr)
        self.kvs.multidelete(idx.stored_keys())

    @property
    def indexes(self) -> Dict[str, SecondaryIndex]:
        return dict(self._indexes)

    # --------------------------------------------------------- cache layer
    def _cache(self):
        """The CachingKVS layer, if one tops the backend stack."""
        return self.kvs if getattr(self.kvs, "is_cache", False) else None

    def _notify_layout_change(self, superseded_keys) -> None:
        """Layout-epoch hook: ``build()`` / ``compact()`` re-partitioned
        chunk storage — flush the cache entries the pass superseded, at the
        same moment open snapshots need ``refresh()`` / re-``snapshot()``.
        (Rewritten keys are already fresh via write-through; this drops the
        deleted old layout's keys even if maintenance bypassed the cache.)"""
        c = self._cache()
        if c is not None:
            c.on_layout_epoch(self._build_epoch + self._layout_epoch,
                              superseded_keys)

    def cache_stats(self) -> Optional[Dict[str, float]]:
        """Hit-rate / occupancy report of the chunk cache layer; ``None``
        when the backend stack has no :class:`~repro.core.cache.CachingKVS`
        on top."""
        c = self._cache()
        return None if c is None else c.cache_report()

    # ------------------------------------------------------------- queries
    def snapshot(self, mode: str = "fresh") -> Snapshot:
        """Immutable read view of the store (the session API).

        ``mode="fresh"`` (default) is read-your-writes: with a
        :class:`~repro.core.flusher.BackgroundFlusher` attached it drains
        first, so every committed version is visible.  Without a flusher,
        ``auto_flush=True`` (seed behaviour) flushes pending deltas first
        while ``auto_flush=False`` makes reads strictly side-effect free
        (unflushed deltas raise — call :meth:`flush` explicitly).

        ``mode="pinned"`` pins the last DURABLE state without flushing
        anything: zero write round trips, bounded staleness.  Versions
        still staged are invisible (querying one fails loudly) and the
        snapshot's ``staleness_lag`` reports how many.  After a *failed*
        drain the in-memory layout is ahead of the durable state, so a
        pinned snapshot raises until a barrier (or backend recovery)
        lands the replay.
        """
        if mode not in ("fresh", "pinned"):
            raise ValueError(f"unknown snapshot mode {mode!r} "
                             "(expected 'fresh' or 'pinned')")
        lag = 0
        if self._flusher is not None:
            if mode == "fresh":
                self._flusher.drain()
            else:
                if self._flusher.has_unacked_writes:
                    raise RuntimeError(
                        "a failed drain left the in-memory layout ahead of "
                        "the durable state; barrier() (or recover the "
                        "backend) before taking a pinned snapshot")
                lag = self._flusher.staleness_lag
        elif self.pending:
            if mode == "pinned":
                lag = len(self.pending)
            elif self._writer is not None and not self._writer._closed:
                # flushing here would split the open session's one group
                # commit into several multiputs behind the caller's back —
                # misuse is loud, like every other mid-session hazard
                raise RuntimeError(
                    f"{len(self.pending)} unflushed version(s) staged by an "
                    "open WriteSession; close the session (its group flush) "
                    "before reading")
            elif self.config.auto_flush:
                self.flush()
            else:
                raise RuntimeError(
                    f"{len(self.pending)} unflushed version(s); call flush() "
                    "explicitly (auto_flush=False makes reads side-effect free)")
        assert self.proj is not None, "no data ingested"
        return Snapshot(self.graph, self.proj, self.kvs,
                        epoch=self._build_epoch,
                        current_epoch=lambda: self._build_epoch,
                        layout_epoch=self._layout_epoch,
                        current_layout_epoch=lambda: self._layout_epoch,
                        indexes=self._indexes,
                        repin=lambda: (self.proj, self._indexes,
                                       self._layout_epoch),
                        staleness_lag=lag,
                        chunk_bytes=self.config.capacity)

    def execute(self, queries) -> "BatchResult":
        """Run a batch of queries against a fresh snapshot (convenience)."""
        return self.snapshot().execute(queries)

    # Back-compat wrappers: each is a single-query session (one KVS round
    # trip; the seed paid two — chunks, then maps).
    def get_version(self, vid: int):
        r = self.snapshot().execute([Q.version(vid)])[0]
        return r.value, r.stats

    def get_record(self, vid: int, pk: int):
        r = self.snapshot().execute([Q.record(vid, pk)])[0]
        return r.value, r.stats

    def get_range(self, vid: int, key_lo: int, key_hi: int):
        r = self.snapshot().execute([Q.range(vid, key_lo, key_hi)])[0]
        return r.value, r.stats

    def get_evolution(self, pk: int):
        r = self.snapshot().execute([Q.evolution(pk)])[0]
        return r.value, r.stats

    # ------------------------------------------------------------- metrics
    def storage_stats(self) -> Dict[str, object]:
        """Chunk/index sizes (plus a ``"cache"`` sub-report when a
        :class:`~repro.core.cache.CachingKVS` tops the backend stack).
        ``stored_chunk_bytes`` is tracked incrementally at chunk-write time
        — the seed multiget every chunk blob just to size it, a full-store
        read per stats call."""
        out = {
            # stored chunks, not the high-water id counter: after a
            # compaction pass the id space is sparse (old ids deleted, new
            # ones appended) but this stays the physical chunk count
            "n_chunks": len(self._chunk_records),
            "stored_chunk_bytes": int(sum(self._chunk_bytes.values())),
            "raw_unique_bytes": int(self.graph.store.sizes.sum()),
        }
        if self.proj is not None:
            out.update(self.proj.compressed_size())
        if self._indexes:
            out["secondary_index_bytes"] = int(sum(
                idx.stored_bytes() for idx in self._indexes.values()))
            out["secondary_indexes"] = {
                attr: idx.report() for attr, idx in self._indexes.items()}
        cache = self.cache_stats()
        if cache is not None:
            out["cache"] = cache
        out["ingest"] = self._ingest_report()
        return out

    def _ingest_report(self) -> Dict[str, object]:
        """The ``storage_stats()["ingest"]`` sub-report: staging state and
        the flusher counters (which live on the top-of-stack ``KVSStats``
        so they ride reset/snapshot/restore/merged like every counter)."""
        fl = self._flusher
        stats = self.kvs.stats
        out: Dict[str, object] = {
            "mode": "async" if fl is not None else "sync",
            "staged_versions": (fl.staged_versions if fl is not None
                                else len(self.pending)),
            "staleness_lag": (fl.staleness_lag if fl is not None
                              else len(self.pending)),
            "n_flush_batches": stats.n_flush_batches,
            "n_versions_staged": stats.n_versions_staged,
            "max_observed_lag": stats.max_observed_lag,
        }
        if fl is not None:
            out.update(
                staged_bytes=fl.staged_bytes,
                clock=fl.step,
                open_sessions=len([w for w in self._async_writers
                                   if not w._closed]),
                pending_replay_writes=len(fl._replay),
                watermarks=fl.watermarks(),
            )
        return out
