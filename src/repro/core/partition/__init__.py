from .base import (ChunkPacker, Partitioner, key_spans, total_version_span,
                   version_spans)
from .baselines import DeltaBaseline, SingleAddressPartitioner, SubChunkPartitioner
from .bottom_up import BottomUpPartitioner
from .shingle import ShinglePartitioner
from .traversal import BFSPartitioner, DFSPartitioner

ALGORITHMS = {
    "bottom_up": BottomUpPartitioner,
    "shingle": ShinglePartitioner,
    "depth_first": DFSPartitioner,
    "breadth_first": BFSPartitioner,
    "single_address": SingleAddressPartitioner,
    "subchunk": SubChunkPartitioner,
    "delta": DeltaBaseline,
}

__all__ = [
    "ChunkPacker", "Partitioner", "version_spans", "total_version_span",
    "key_spans", "BottomUpPartitioner", "ShinglePartitioner", "DFSPartitioner",
    "BFSPartitioner", "SingleAddressPartitioner", "SubChunkPartitioner",
    "DeltaBaseline", "ALGORITHMS",
]
