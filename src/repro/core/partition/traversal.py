"""DEPTH-FIRST / BREADTH-FIRST greedy partitioning (§3.3, Algorithm 4).

Traverse the version tree from the root; at each newly visited version, pack
the records of its Δ+ (relative to the tree parent) into the open chunk.
DFS keeps a parent's records adjacent to its descendants' (Example 5's
option (b)); BFS interleaves siblings and is uniformly worse except on
chains, where both reduce to the same order — exactly the paper's claim,
which test_partition_traversal.py asserts.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..types import Partitioning
from ..version_graph import VersionGraph
from .base import ChunkPacker


def _traverse(graph: VersionGraph, order, name: str, capacity: int) -> Partitioning:
    packer = ChunkPacker(graph.store.sizes, capacity)
    keys = graph.store.keys()
    # retention GC: deltas of retired versions may carry records reachable
    # from no retained version — a rebuild must not resurrect that garbage
    live = graph.live_record_mask() if graph.has_retired() else None
    for v in order:
        adds = graph.tree_delta[v].adds
        if live is not None:
            adds = adds[live[adds]]
        # deterministic within-delta order: by primary key
        adds = adds[np.argsort(keys[adds], kind="stable")]
        packer.place_many(adds, dedupe=True)  # dedupe: merge-sourced repeats
    return packer.finish(name)


@dataclass
class DFSPartitioner:
    name: str = "depth_first"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        return _traverse(graph, graph.dfs_order(), self.name, capacity)


@dataclass
class BFSPartitioner:
    name: str = "breadth_first"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        return _traverse(graph, graph.bfs_order(), self.name, capacity)
