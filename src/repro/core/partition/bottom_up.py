"""BOTTOM-UP partitioning (§3.2, Algorithm 3) — the paper's best algorithm.

The version tree is processed children-before-parent.  Each processed version
``v`` hands its parent a collection π_v of record sets tagged with a *depth*:
the number of consecutive versions (starting at ``v``, going down) known to
contain those records.  At ``v``:

  - a child set ``(j, S)`` splits into ``S ∩ members(v)`` (consecutive run
    extends: depth ``j+1`` in π_v) and ``S \\ members(v)`` (the run breaks —
    these are the paper's α sets and are *finalized*, i.e. chunked now,
    deepest-first, starting a fresh chunk at each version);
  - records of ``v`` present in no child form the new depth-1 set S_v^1.

At the root everything remaining is finalized.  Two paper-specified
refinements for general trees are implemented: sets of equal depth coming
from different children are unioned ("sets from different children that
correspond to same number of consecutive versions are chunked together"),
and duplicates (records reachable via several branches after the Fig. 4
DAG→tree conversion) are dropped at placement time via the packer's placed
bitmap ("a hash-table is maintained to identify records that have already
been chunked").

β subtree control (§3.2.1): when π_v holds more than β depth-sets, the
deepest set is merged into the next-deepest until |π_v| ≤ β — the exact
"merge leaves into parents" reduction specialized to the depth-collection
representation.  Partial chunks are merged at the end (the paper's
fragmentation cleanup).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from ..types import Partitioning
from ..version_graph import VersionGraph
from .base import ChunkPacker


def _intersect(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.intersect1d(a, b, assume_unique=True)


def _setdiff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.setdiff1d(a, b, assume_unique=True)


@dataclass
class BottomUpPartitioner:
    beta: int = 64          # §3.2.1 subtree (set-collection) bound
    name: str = "bottom_up"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        packer = ChunkPacker(graph.store.sizes, capacity)
        # π per processed version: dict depth -> sorted record-id array
        pis: Dict[int, Dict[int, np.ndarray]] = {}

        for v in graph.postorder():
            members = graph.members(v)
            children = graph.tree_children(v)
            pi_v: Dict[int, np.ndarray] = {}
            finalized: List[Tuple[int, np.ndarray]] = []

            for c in children:
                pi_c = pis.pop(c)
                for depth, s in pi_c.items():
                    stay = _intersect(s, members)
                    gone = _setdiff(s, members)
                    if gone.size:
                        finalized.append((depth, gone))
                    if stay.size:
                        d = depth + 1
                        pi_v[d] = (np.union1d(pi_v[d], stay)
                                   if d in pi_v else stay)

            # records of v in no child → new depth-1 set
            covered = (np.unique(np.concatenate([s for s in pi_v.values()]))
                       if pi_v else np.empty(0, np.int64))
            fresh = _setdiff(members, covered)
            if fresh.size:
                pi_v[1] = np.union1d(pi_v[1], fresh) if 1 in pi_v else fresh

            # β control: cap the number of depth-sets by merging deepest pairs
            while len(pi_v) > self.beta:
                depths = sorted(pi_v)
                d1 = depths[-1]            # deepest
                d2 = depths[-2]
                pi_v[d2] = np.union1d(pi_v[d2], pi_v.pop(d1))

            # chunk finalized α sets, deepest (most-consecutive) first; a new
            # chunk starts at every version's finalization step
            if finalized:
                packer.boundary()
                for depth, s in sorted(finalized, key=lambda t: -t[0]):
                    packer.place_many(s, dedupe=True)

            pis[v] = pi_v

        # root: everything still in flight is finalized, deepest-first
        root_pi = pis.pop(graph.root)  # type: ignore[arg-type]
        packer.boundary()
        for depth in sorted(root_pi, reverse=True):
            packer.place_many(root_pi[depth], dedupe=True)

        return packer.finish(self.name)
