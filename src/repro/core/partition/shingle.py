"""SHINGLE partitioning (§3.1, Algorithms 1–2).

For every record, compute ``l`` min-hashes of its version-membership set
(the Pallas ``minhash`` kernel does the hashing), sort records
lexicographically by their shingle vectors — which places records with
highly-overlapping version sets next to each other — and pack them into
fixed-size chunks in that order.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...kernels import ops as kops
from ..types import Partitioning
from ..version_graph import VersionGraph
from .base import ChunkPacker


@dataclass
class ShinglePartitioner:
    n_hashes: int = 8
    seed: int = 0
    name: str = "shingle"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        indptr, vidx = graph.record_version_index_csr()
        a, b = kops.hash_family(self.n_hashes, self.seed)
        shingles = kops.minhash_csr(indptr, vidx.astype(np.int64), a, b)  # (R, L)
        # lexicographic order over the shingle vector; ties broken by origin
        # version then primary key for determinism.
        keys = graph.store.keys()
        origins = graph.store.origin_versions()
        order = np.lexsort((keys, origins) + tuple(shingles[:, l]
                           for l in range(self.n_hashes - 1, -1, -1)))
        # retention GC: a record in no version (empty CSR row — all its
        # versions were retired) is garbage and must not be re-chunked
        degree = np.diff(indptr)
        order = order[degree[order] > 0]
        packer = ChunkPacker(graph.store.sizes, capacity)
        packer.place_many(order)
        return packer.finish(self.name)
