"""Partitioner API + the fixed-size chunk packer (§2.5 fixed chunk size).

All partitioning algorithms produce a :class:`Partitioning` by streaming
record ids (in an algorithm-specific order) into a :class:`ChunkPacker` that
enforces the paper's fixed-chunk-size design decision: chunks target capacity
``C`` bytes with up to ``slack`` (default 25%) overflow allowed, and partial
chunks created at forced boundaries are merged at the end to reduce
fragmentation (§3.2).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence

import numpy as np

from ..types import Chunk, Partitioning
from ..version_graph import VersionGraph


class Partitioner(Protocol):
    name: str

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning: ...


class ChunkPacker:
    """Sequentially packs records into ~equal-sized chunks.

    - ``place(rid)`` appends a record to the open chunk, closing it when the
      next record would push it past ``C*(1+slack)``.
    - ``boundary()`` force-closes the open chunk (used by BOTTOM-UP at each
      version's finalization step so that "highly common" records are not
      split across chunks).
    - ``finish(merge_partial=True)`` merges under-half-full chunks (in
      creation order, preserving locality) and emits the Partitioning.
    Oversized single records get a dedicated (over-slack) chunk, mirroring the
    paper's handling of records comparable to the chunk size.
    """

    def __init__(self, record_sizes: np.ndarray, capacity: int,
                 slack: float = 0.25) -> None:
        self.sizes = record_sizes
        self.capacity = int(capacity)
        self.slack = float(slack)
        self.limit = int(capacity * (1 + slack))
        self._chunks: List[List[int]] = []
        self._chunk_bytes: List[int] = []
        self._cur: List[int] = []
        self._cur_bytes = 0
        self._placed = np.zeros(len(record_sizes), dtype=bool)

    # ------------------------------------------------------------ placement
    def is_placed(self, rid: int) -> bool:
        return bool(self._placed[rid])

    def place(self, rid: int) -> None:
        if self._placed[rid]:
            raise ValueError(f"record {rid} placed twice")
        sz = int(self.sizes[rid])
        if self._cur and self._cur_bytes + sz > self.limit:
            self._close()
        self._cur.append(int(rid))
        self._cur_bytes += sz
        self._placed[rid] = True
        if self._cur_bytes >= self.capacity:
            self._close()

    def place_many(self, rids: Sequence[int], dedupe: bool = False) -> None:
        for r in rids:
            r = int(r)
            if dedupe and self._placed[r]:
                continue
            self.place(r)

    def boundary(self) -> None:
        if self._cur:
            self._close()

    def _close(self) -> None:
        self._chunks.append(self._cur)
        self._chunk_bytes.append(self._cur_bytes)
        self._cur = []
        self._cur_bytes = 0

    # -------------------------------------------------------------- sealing
    def finish(self, algorithm: str, merge_partial: bool = True) -> Partitioning:
        self.boundary()
        chunks_r = self._chunks
        bytes_r = self._chunk_bytes
        if merge_partial:
            chunks_r, bytes_r = self._merge_partial(chunks_r, bytes_r)
        chunks = []
        r2c = np.full(len(self.sizes), -1, dtype=np.int64)
        for cid, (rids, nb) in enumerate(zip(chunks_r, bytes_r)):
            arr = np.asarray(rids, dtype=np.int64)
            chunks.append(Chunk(chunk_id=cid, record_ids=arr, nbytes=nb))
            r2c[arr] = cid
        return Partitioning(chunks=chunks, record_to_chunk=r2c, algorithm=algorithm)

    def _merge_partial(self, chunks: List[List[int]], cbytes: List[int]):
        """First-fit merge of partial (< C/2) chunks in creation order."""
        out_chunks: List[List[int]] = []
        out_bytes: List[int] = []
        open_idx: Optional[int] = None  # index in out of a partial merge target
        for rids, nb in zip(chunks, cbytes):
            if nb >= self.capacity // 2:
                out_chunks.append(rids)
                out_bytes.append(nb)
                continue
            if open_idx is not None and out_bytes[open_idx] + nb <= self.limit:
                out_chunks[open_idx] = out_chunks[open_idx] + rids
                out_bytes[open_idx] += nb
                if out_bytes[open_idx] >= self.capacity // 2:
                    open_idx = None
            else:
                out_chunks.append(rids)
                out_bytes.append(nb)
                open_idx = len(out_chunks) - 1 if nb < self.capacity // 2 else None
        return out_chunks, out_bytes


# --------------------------------------------------------------------- span
def version_spans(graph: VersionGraph, part: Partitioning) -> Dict[int, int]:
    """Span of every full-version-retrieval query (§2.5): number of distinct
    chunks holding the version's records."""
    r2c = part.record_to_chunk
    return {v: int(np.unique(r2c[m]).size) for v, m in graph.memberships().items()}


def total_version_span(graph: VersionGraph, part: Partitioning) -> int:
    """The paper's Fig. 8 metric: Σ_v span(v)."""
    return int(sum(version_spans(graph, part).values()))


def key_spans(graph: VersionGraph, part: Partitioning) -> Dict[int, int]:
    """Span of every record-evolution query: chunks per primary key."""
    r2c = part.record_to_chunk
    keys = graph.store.keys()
    out: Dict[int, int] = {}
    order = np.argsort(keys, kind="stable")
    ks = keys[order]
    cs = r2c[order]
    bounds = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1], True])
    for i in range(len(bounds) - 1):
        lo, hi = bounds[i], bounds[i + 1]
        out[int(ks[lo])] = int(np.unique(cs[lo:hi]).size)
    return out
