"""Baseline storage schemes (§2.2, Table 1 rivals).

- SINGLE-ADDRESS: one KVS entry per record (chunk of one) — best ingest,
  no compression, span(v) = |v|.
- SUBCHUNK: all records of a primary key in one (unbounded) group — best
  storage & evolution queries, catastrophic version retrieval.
- DELTA: git-style delta chains packed into fixed-size chunks in commit
  order; reconstructing ``v`` touches every chunk holding any delta content
  on the root→v path (including records later overwritten — the reason
  key-centric queries are "abysmal").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from ..types import Chunk, Partitioning
from ..version_graph import VersionGraph
from .base import ChunkPacker


@dataclass
class SingleAddressPartitioner:
    name: str = "single_address"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        n = len(graph.store)
        chunks = [Chunk(i, np.array([i], dtype=np.int64), int(graph.store.sizes[i]))
                  for i in range(n)]
        return Partitioning(chunks=chunks,
                            record_to_chunk=np.arange(n, dtype=np.int64),
                            algorithm=self.name)


@dataclass
class SubChunkPartitioner:
    """One group per primary key (k = ∞).  Violates the fixed-chunk-size
    assumption by design — do not validate() capacity on its output."""

    name: str = "subchunk"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        keys = graph.store.keys()
        order = np.argsort(keys, kind="stable")
        ks = keys[order]
        bounds = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1], True])
        chunks = []
        r2c = np.full(len(keys), -1, dtype=np.int64)
        sizes = graph.store.sizes
        for cid in range(len(bounds) - 1):
            rids = order[bounds[cid]:bounds[cid + 1]]
            chunks.append(Chunk(cid, np.sort(rids), int(sizes[rids].sum())))
            r2c[rids] = cid
        return Partitioning(chunks=chunks, record_to_chunk=r2c, algorithm=self.name)


@dataclass
class DeltaBaseline:
    """Delta chains.  Produces a Partitioning (records packed by commit order
    of their origin version = the physical delta stream) plus the DELTA-
    specific span semantics."""

    name: str = "delta"

    def partition(self, graph: VersionGraph, capacity: int) -> Partitioning:
        packer = ChunkPacker(graph.store.sizes, capacity)
        live = graph.live_record_mask() if graph.has_retired() else None
        for v in graph.versions:  # commit order
            adds = graph.tree_delta[v].adds
            if live is not None:
                adds = adds[live[adds]]
            packer.place_many(adds, dedupe=True)
        # no boundary merging: the stream layout *is* the baseline
        return packer.finish(self.name, merge_partial=False)

    def version_spans(self, graph: VersionGraph, part: Partitioning) -> Dict[int, int]:
        """span(v) = unique chunks holding delta content of any version on the
        root→v path (the whole chain must be read and replayed)."""
        r2c = part.record_to_chunk
        chunks_of: Dict[int, np.ndarray] = {}
        spans: Dict[int, int] = {}
        for v in graph.versions:
            own = np.unique(r2c[graph.tree_delta[v].adds])
            p = graph.tree_parent(v)
            acc = own if p is None else np.union1d(chunks_of[p], own)
            chunks_of[v] = acc
            spans[v] = int(acc.size)
        return spans

    def total_version_span(self, graph: VersionGraph, part: Partitioning) -> int:
        return int(sum(self.version_spans(graph, part).values()))
