"""Lossy projections + posting-list compression (§2.4, Fig. 3b).

Two in-memory maps answer "which chunks might hold what I need":
  - version→chunks (drives Q1 full version retrieval),
  - key→chunks     (drives Q3 record evolution).
Record/range retrieval ANDs the two (index-ANDing) — realized with the
``bitmap`` Pallas kernel over chunk-membership bitmaps; a whole session of
queries is planned in ONE pairwise kernel launch (``candidates_batch``), and
range predicates locate their keys via ``searchsorted`` over a cached sorted
key array rather than scanning the key dictionary.  Both lists are
*lossy*: a fetched chunk may turn out to hold no relevant record (the paper
notes this explicitly); the exact information lives in the per-chunk maps.

Posting lists are stored delta+varint compressed (the paper's pointer to the
inverted-index literature) with ``compressed_size`` exposed so benchmarks can
reproduce the §2.4 index-size discussion.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ops as kops
from .types import Partitioning
from .version_graph import VersionGraph


# ------------------------------------------------------------------- varints
def varint_encode(arr: np.ndarray) -> bytes:
    """Delta + LEB128 varint encoding of a sorted non-negative int array.

    Vectorized: byte counts, byte values, and continuation bits are computed
    for the whole array at once; the only Python loop is over the (≤10)
    byte *positions* of the widest delta, not over array elements.  The byte
    format is the classic little-endian 7-bit-group LEB128 the original
    per-element loop produced.
    """
    a = np.asarray(arr, dtype=np.int64)
    if len(a) == 0:
        return b""
    d = np.empty(len(a), dtype=np.uint64)
    d[0] = a[0]
    np.subtract(a[1:], a[:-1], out=d[1:], casting="unsafe")
    # bytes needed per delta: ceil(bit_length / 7), minimum 1
    nbytes = np.ones(len(d), dtype=np.int64)
    rest = d >> np.uint64(7)
    while rest.any():
        nbytes += (rest > 0)
        rest >>= np.uint64(7)
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    out = np.empty(int(ends[-1]), dtype=np.uint8)
    for j in range(int(nbytes.max())):
        m = nbytes > j
        b = ((d[m] >> np.uint64(7 * j)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nbytes[m] - 1 > j).astype(np.uint8) << 7
        out[starts[m] + j] = b | cont
    return out.tobytes()


def varint_decode(buf: bytes) -> np.ndarray:
    """Inverse of :func:`varint_encode` (vectorized).

    Each encoded group's bytes are OR'd into its value in one scatter per
    byte *position*; a trailing incomplete group (continuation bit set on
    the final byte) is discarded, matching the original decoder.
    """
    a = np.frombuffer(buf, dtype=np.uint8)
    if len(a) == 0:
        return np.empty(0, dtype=np.int64)
    is_last = (a & 0x80) == 0
    n_groups = int(is_last.sum())
    # group index of every byte: groups end at terminator bytes
    grp = np.zeros(len(a), dtype=np.int64)
    grp[1:] = np.cumsum(is_last[:-1])
    idx = np.arange(len(a), dtype=np.int64)
    group_start = np.empty(n_groups + 1, dtype=np.int64)
    group_start[0] = 0
    group_start[1:] = idx[is_last] + 1
    pos = idx - group_start[grp]
    vals = np.zeros(n_groups, dtype=np.uint64)
    complete = grp < n_groups          # drop a trailing incomplete group
    np.bitwise_or.at(
        vals, grp[complete],
        (a[complete] & np.uint8(0x7F)).astype(np.uint64)
        << (np.uint64(7) * pos[complete].astype(np.uint64)))
    return np.cumsum(vals.astype(np.int64))


# --------------------------------------------------------------- projections
@dataclass
class Projections:
    version_chunks: Dict[int, np.ndarray]   # vid -> sorted chunk ids
    key_chunks: Dict[int, np.ndarray]       # pk  -> sorted chunk ids
    n_chunks: int
    # sorted primary-key array (lazy cache) backing O(log n) range lookups.
    # Staleness contract: the cache covers the key *set* only (not the
    # posting lists), and _keys_dirty is set explicitly by every mutation
    # that can grow the key set (extend_keys) — adding chunks to an
    # *existing* key leaves the cache valid and does not rebuild it.
    _sorted_keys: Optional[np.ndarray] = field(default=None, repr=False,
                                               compare=False)
    _keys_dirty: bool = field(default=True, repr=False, compare=False)

    # -------------------------------------------------------------- building
    @staticmethod
    def build(graph: VersionGraph, part: Partitioning) -> "Projections":
        """Build both projections from a record→chunk map.  Unplaced records
        (``r2c == -1``: retention garbage dropped by compaction or a
        retention-aware rebuild) are simply absent from the index."""
        r2c = part.record_to_chunk
        vc = {}
        for v, m in graph.memberships().items():
            cs_v = np.unique(r2c[m])
            vc[v] = cs_v[cs_v >= 0]
        keys = graph.store.keys()
        kc: Dict[int, np.ndarray] = {}
        order = np.argsort(keys, kind="stable")
        ks = keys[order]
        cs = r2c[order]
        bounds = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1], True])
        for i in range(len(bounds) - 1):
            lo, hi = bounds[i], bounds[i + 1]
            ids = np.unique(cs[lo:hi])
            ids = ids[ids >= 0]
            if len(ids):
                kc[int(ks[lo])] = ids
        return Projections(version_chunks=vc, key_chunks=kc,
                           n_chunks=part.num_chunks)

    @staticmethod
    def build_from_r2c(graph: VersionGraph, r2c: np.ndarray,
                       n_chunks: int) -> "Projections":
        class _P:  # minimal Partitioning stand-in
            record_to_chunk = r2c
            num_chunks = n_chunks
        return Projections.build(graph, _P())  # type: ignore[arg-type]

    # -------------------------------------------------------------- lookups
    def chunks_for_version(self, vid: int) -> np.ndarray:
        return self.version_chunks[vid]

    def chunks_for_key(self, pk: int) -> np.ndarray:
        return self.key_chunks.get(pk, np.empty(0, np.int64))

    # ------------------------------------------------------- index-ANDing
    def _bitmap_of(self, chunk_ids: np.ndarray) -> np.ndarray:
        W = (self.n_chunks + 31) // 32
        bm = np.zeros(W, dtype=np.uint32)
        np.bitwise_or.at(bm, chunk_ids // 32,
                         np.uint32(1) << (chunk_ids % 32).astype(np.uint32))
        return bm

    def candidates(self, vid: int, pks: Iterable[int]) -> np.ndarray:
        """Chunks possibly holding records of any of ``pks`` within version
        ``vid``: AND of the key bitmaps with the version bitmap, OR'd across
        keys.  Single-query form of :meth:`candidates_batch`."""
        return self.candidates_batch([(vid, pks)])[0]

    def candidates_batch(
            self, items: Sequence[Tuple[int, Iterable[int]]],
    ) -> List[np.ndarray]:
        """Plan a whole batch of index-AND queries in ONE kernel launch.

        ``items`` is a list of ``(vid, pks)`` pairs — one per point/multi-
        point/range query in a session.  Per query, the key posting lists
        are OR'd on the host (cheap: W words each) into one row; the rest
        is :meth:`and_version_batch`.
        """
        return self.and_version_batch(
            [(vid, [self.key_chunks.get(pk) for pk in pks])
             for vid, pks in items])

    def and_version_batch(
            self, items: Sequence[Tuple[int, Sequence[Optional[np.ndarray]]]],
    ) -> List[np.ndarray]:
        """AND arbitrary chunk-id posting lists against version bitmaps in
        ONE pairwise kernel launch.

        Each item is ``(vid, posting_lists)``: the posting lists (any
        chunk-granularity source — primary-key postings, secondary-attribute
        postings; ``None``/empty entries allowed) are OR'd into one bitmap
        row, and the N OR'd rows are AND'd pairwise against the N version
        rows by a single ``and_popcount_batch`` call (the (N, W) & (N, W)
        kernel path).  Returns one sorted chunk-id array per item.
        """
        if not items:
            return []
        W = (self.n_chunks + 31) // 32
        key_rows = np.zeros((len(items), max(W, 1)), dtype=np.uint32)
        ver_rows = np.zeros((len(items), max(W, 1)), dtype=np.uint32)
        nonempty = np.zeros(len(items), dtype=bool)
        for i, (vid, postings) in enumerate(items):
            ver_rows[i] = self._bitmap_of(self.version_chunks[vid])
            for ids in postings:
                if ids is not None and len(ids):
                    np.bitwise_or.at(key_rows[i], ids // 32,
                                     np.uint32(1) << (ids % 32).astype(np.uint32))
                    nonempty[i] = True
        anded, _ = kops.and_popcount_batch(key_rows, ver_rows)
        empty = np.empty(0, np.int64)
        return [_bitmap_to_ids(anded[i], self.n_chunks) if nonempty[i] else empty
                for i in range(len(items))]

    # ----------------------------------------------------------- key ranges
    def sorted_keys(self) -> np.ndarray:
        """All indexed primary keys, sorted.

        Cached behind an explicit dirty flag: ``extend_keys`` marks the
        cache dirty exactly when it adds a primary key the index did not
        hold before (the earlier ``len(...) != len(...)`` heuristic could
        not distinguish "new keys" from "same keys, more chunks", and would
        silently go stale on any future mutation that swapped keys while
        preserving the count)."""
        if self._sorted_keys is None or self._keys_dirty:
            self._sorted_keys = np.sort(np.fromiter(
                self.key_chunks.keys(), dtype=np.int64, count=len(self.key_chunks)))
            self._keys_dirty = False
        return self._sorted_keys

    def keys_in_range(self, key_lo: int, key_hi: int) -> np.ndarray:
        """Indexed keys in [key_lo, key_hi] — O(log n + m) via searchsorted
        over the sorted key array (not an O(all-keys) dict scan)."""
        ks = self.sorted_keys()
        lo = np.searchsorted(ks, key_lo, side="left")
        hi = np.searchsorted(ks, key_hi, side="right")
        return ks[lo:hi]

    def candidates_range(self, vid: int, key_lo: int, key_hi: int) -> np.ndarray:
        return self.candidates(vid, self.keys_in_range(key_lo, key_hi))

    # ----------------------------------------------------------- index size
    def compressed_size(self) -> Dict[str, int]:
        v = sum(len(varint_encode(c)) for c in self.version_chunks.values())
        k = sum(len(varint_encode(c)) for c in self.key_chunks.values())
        return {"version_chunks_bytes": v, "key_chunks_bytes": k}

    def raw_size(self) -> Dict[str, int]:
        v = sum(8 * len(c) for c in self.version_chunks.values())
        k = sum(8 * len(c) for c in self.key_chunks.values())
        return {"version_chunks_bytes": v, "key_chunks_bytes": k}

    # ------------------------------------------------------ online updates
    def extend_version(self, vid: int, chunk_ids: np.ndarray) -> None:
        self.version_chunks[vid] = np.unique(chunk_ids)

    def drop_versions(self, vids: Iterable[int]) -> None:
        """Retention: retired versions leave the version→chunks projection
        so queries against them fail loudly at plan time.  Key postings are
        left alone — they are lossy by design, and compaction rebuilds them
        when the dead chunks actually go away."""
        for v in vids:
            self.version_chunks.pop(v, None)

    def extend_keys(self, pk_to_chunks: Dict[int, np.ndarray]) -> None:
        for pk, cs in pk_to_chunks.items():
            old = self.key_chunks.get(pk)
            if old is None:
                self.key_chunks[pk] = np.unique(cs)
                self._keys_dirty = True      # key set grew: sorted cache stale
            else:
                # same key set, more chunks: sorted_keys cache stays valid
                self.key_chunks[pk] = np.union1d(old, cs)

    def grow(self, n_chunks: int) -> None:
        self.n_chunks = max(self.n_chunks, n_chunks)


def _bitmap_to_ids(bm: np.ndarray, n: int) -> np.ndarray:
    bits = np.unpackbits(bm.view(np.uint8), bitorder="little")[:n]
    return np.flatnonzero(bits).astype(np.int64)
