"""Chunk cache: cost-model-driven read-through cache over any backend.

:class:`CachingKVS` implements the full :class:`~repro.core.kvs.Backend`
protocol and stacks over any existing backend (``InMemoryKVS``,
``ShardedKVS``, ``ShardedDeviceKVS``, ``ReplicatedKVS``), attacking the
paper's storage-vs-retrieval trade-off online: hot chunks are served at
memory speed while the offline layout algorithms stay unchanged.

Three design pillars:

**Byte-budget segmented LRU with cost-model admission.**  Entries live in a
probation segment on first fill and are promoted to a protected segment on
re-reference (classic SLRU: one hit in probation proves reuse, so scans of
cold chunks can't flush the hot set).  When admitting a new entry would
force evictions, the entry is admitted only if its predicted re-fetch cost
(per-query overhead + bytes/bandwidth, priced by
:func:`repro.core.costmodel.fetch_seconds`) is at least the combined
re-fetch cost of the victims it displaces — the per-query overhead term is
what makes many small hot chunks worth more than one big cold one.  Tiny
blobs (chunk maps are a few KB next to 64 KB chunk payloads) bypass the
comparison: they always win it in practice and sit on every read path.

**Strict coherence.**  Every mutation path in the system — session flush,
``build()``, compaction — flows through ``multiput``/``multidelete``, so the
cache (a) drops its copies of the touched keys *before* forwarding the write
(a partial backend failure can then only leave the cache cold, never stale)
and (b) re-admits written values after the backend acknowledges
(write-through).  ``on_layout_epoch`` is the belt-and-braces hook on top:
``rs.compact()`` and ``build()`` report the keys their re-partitioning
superseded, exactly the moment ``Snapshot.refresh()`` re-pins, guarding the
cache even against maintenance that mutates a backend below this layer.

**Honest round-trip accounting.**  ``stats.n_queries`` (and the other
read/write counters) mirror only *actual* inner-backend traffic, measured as
deltas around forwarded calls — a fully warm ``multiget`` is 0 round trips,
which is precisely what ``Snapshot.execute``'s per-batch ``kvs_queries``
then reports.  Cache-served traffic is counted separately in the
``n_cache_hits`` / ``n_cache_misses`` / ``bytes_served_from_cache`` fields
of :class:`~repro.core.kvs.KVSStats`.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .costmodel import BANDWIDTH_BPS, PER_QUERY_S, fetch_seconds
from .kvs import Backend, KVSStats

# Per-entry bookkeeping charge on top of key+value bytes (dict slots,
# OrderedDict links) so the byte budget bounds real memory, not just payload.
ENTRY_OVERHEAD = 64


class CachingKVS:
    """Read-through cache wrapping ``inner``; full Backend protocol.

    Parameters
    ----------
    inner : Backend to serve misses from and forward writes to.
    cache_bytes : byte budget; charged bytes (value+key+overhead) never
        exceed it.
    protected_frac : share of the budget the protected segment may hold
        before its LRU entries demote back to probation.
    always_admit_bytes : values at or under this size skip the cost-model
        admission comparison (chunk-map blobs always cached).
    per_query_s / bandwidth_Bps : re-fetch pricing, defaulting to the
        system-wide §2.3 constants in :mod:`repro.core.costmodel`.
    """

    # Discovery marker: RStore.cache_stats() / storage_stats() and
    # Snapshot.prefetch* find the cache layer through this instead of an
    # isinstance check, so wrappers composing CachingKVS keep working.
    is_cache = True

    def __init__(self, inner: Backend, cache_bytes: int = 64 << 20,
                 protected_frac: float = 0.8,
                 always_admit_bytes: int = 4096,
                 per_query_s: float = PER_QUERY_S,
                 bandwidth_Bps: float = BANDWIDTH_BPS) -> None:
        if cache_bytes <= 0:
            raise ValueError("cache_bytes must be positive")
        if not (0.0 < protected_frac < 1.0):
            raise ValueError("protected_frac must be in (0, 1)")
        self.inner = inner
        self.cache_bytes = int(cache_bytes)
        self.protected_frac = float(protected_frac)
        self.always_admit_bytes = int(always_admit_bytes)
        self.per_query_s = float(per_query_s)
        self.bandwidth_Bps = float(bandwidth_Bps)
        self.stats = KVSStats()
        # Both segments are OrderedDicts in LRU→MRU order.
        self._probation: "OrderedDict[str, bytes]" = OrderedDict()
        self._protected: "OrderedDict[str, bytes]" = OrderedDict()
        self._cached_bytes = 0      # charged bytes across both segments
        self._protected_bytes = 0   # charged bytes in protected only
        self.layout_epoch = 0       # last epoch reported via on_layout_epoch
        self.n_evictions = 0
        self.n_admit_rejected = 0
        self.n_invalidations = 0
        self.n_write_through = 0    # write-through re-admissions (per key)

    # ---------------------------------------------------------------- sizing

    @staticmethod
    def _charge(key: str, value: bytes) -> int:
        return len(value) + len(key) + ENTRY_OVERHEAD

    @property
    def cached_bytes(self) -> int:
        return self._cached_bytes

    @property
    def n_entries(self) -> int:
        return len(self._probation) + len(self._protected)

    # ------------------------------------------------------------ SLRU core

    def _lookup(self, key: str) -> Optional[bytes]:
        """Hit path: protected hits refresh recency; probation hits promote
        (the second reference is the reuse signal SLRU keys on)."""
        if key in self._protected:
            self._protected.move_to_end(key)
            return self._protected[key]
        if key in self._probation:
            v = self._probation.pop(key)
            self._protected[key] = v
            self._protected_bytes += self._charge(key, v)
            self._shrink_protected()
            return v
        return None

    def _shrink_protected(self) -> None:
        """Demote protected-LRU entries back to probation MRU once the
        segment outgrows its share — they get one more chance before
        leaving the cache entirely."""
        cap = self.protected_frac * self.cache_bytes
        while self._protected_bytes > cap and len(self._protected) > 1:
            k, v = self._protected.popitem(last=False)
            self._protected_bytes -= self._charge(k, v)
            self._probation[k] = v

    def _pop(self, key: str) -> Optional[bytes]:
        if key in self._probation:
            v = self._probation.pop(key)
        elif key in self._protected:
            v = self._protected.pop(key)
            self._protected_bytes -= self._charge(key, v)
        else:
            return None
        self._cached_bytes -= self._charge(key, v)
        return v

    def _victims(self) -> Iterable[Tuple[str, bytes]]:
        """Eviction order: probation LRU→MRU, then protected LRU→MRU."""
        yield from self._probation.items()
        yield from self._protected.items()

    def _evict(self, need: int) -> None:
        freed = 0
        while freed < need:
            if self._probation:
                k, v = self._probation.popitem(last=False)
            elif self._protected:
                k, v = self._protected.popitem(last=False)
                self._protected_bytes -= self._charge(k, v)
            else:
                break
            c = self._charge(k, v)
            self._cached_bytes -= c
            freed += c
            self.n_evictions += 1

    def _admit(self, key: str, value: bytes) -> bool:
        """Insert into probation if the cost model approves.

        Free budget admits unconditionally.  When eviction would be forced,
        the candidate's re-fetch price must beat the summed re-fetch price
        of the victims it displaces (each priced as one round trip + its
        transfer time — an upper bound, since real misses batch, but the
        same bound on both sides keeps the comparison fair).  Values at or
        under ``always_admit_bytes`` skip the comparison.
        """
        size = self._charge(key, value)
        if size > self.cache_bytes:
            self.n_admit_rejected += 1
            return False
        if key in self._probation or key in self._protected:
            self._refresh(key, value)
            return True
        need = self._cached_bytes + size - self.cache_bytes
        if need > 0 and len(value) > self.always_admit_bytes:
            victims_cost = 0.0
            freed = 0
            for k, v in self._victims():
                if freed >= need:
                    break
                freed += self._charge(k, v)
                victims_cost += fetch_seconds(1, len(v), self.per_query_s,
                                              self.bandwidth_Bps)
            if fetch_seconds(1, len(value), self.per_query_s,
                             self.bandwidth_Bps) < victims_cost:
                self.n_admit_rejected += 1
                return False
        if need > 0:
            self._evict(need)
        self._probation[key] = value
        self._cached_bytes += size
        return True

    def _refresh(self, key: str, value: bytes) -> None:
        """Replace a cached entry's bytes in place (same segment, same
        recency), re-evicting if the new value grew past the budget."""
        for seg in (self._probation, self._protected):
            if key in seg:
                delta = len(value) - len(seg[key])
                seg[key] = value
                self._cached_bytes += delta
                if seg is self._protected:
                    self._protected_bytes += delta
                if self._cached_bytes > self.cache_bytes:
                    self._evict(self._cached_bytes - self.cache_bytes)
                return

    # ------------------------------------------------------------ coherence

    def invalidate(self, keys: Iterable[str]) -> int:
        """Drop any cached copies of ``keys``; returns how many were held."""
        n = 0
        for k in keys:
            if self._pop(k) is not None:
                n += 1
        self.n_invalidations += n
        return n

    def clear(self) -> None:
        self.n_invalidations += self.n_entries
        self._probation.clear()
        self._protected.clear()
        self._cached_bytes = 0
        self._protected_bytes = 0

    def on_layout_epoch(self, epoch: int,
                        touched_keys: Optional[Iterable[str]] = None) -> None:
        """Layout-change hook: ``build()`` / ``compact()`` re-partitioned
        chunk storage; flush every entry the pass superseded (all entries
        when ``touched_keys`` is None).  Redundant with write-through /
        delete-invalidation when every mutation flows through this layer —
        load-bearing when maintenance mutates a backend below it."""
        self.layout_epoch = epoch
        if touched_keys is None:
            self.clear()
        else:
            self.invalidate(touched_keys)

    # ----------------------------------------------------------- read path

    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        if not keys:           # PR-2 convention: no round trip, stats untouched
            return []
        out: List[Optional[bytes]] = [None] * len(keys)
        misses: List[int] = []
        for i, k in enumerate(keys):
            v = self._lookup(k)
            if v is None:
                misses.append(i)
            else:
                out[i] = v
                self.stats.n_cache_hits += 1
                self.stats.bytes_served_from_cache += len(v)
        if misses:
            s = self.inner.stats
            q0, n0, b0 = s.n_queries, s.n_values, s.bytes_fetched
            vals = self.inner.multiget([keys[i] for i in misses])
            self.stats.n_queries += s.n_queries - q0
            self.stats.n_values += s.n_values - n0
            self.stats.bytes_fetched += s.bytes_fetched - b0
            self.stats.n_cache_misses += len(misses)
            for i, v in zip(misses, vals):
                out[i] = v
                self._admit(keys[i], v)
        return out  # type: ignore[return-value]

    def get(self, key: str) -> bytes:
        return self.multiget([key])[0]

    def scan(self) -> List[Tuple[str, bytes]]:
        """Recovery primitive: forwarded verbatim, and deliberately NOT
        admitted — one scan of a big store would flush the whole hot set."""
        s = self.inner.stats
        q0, n0, b0 = s.n_queries, s.n_values, s.bytes_fetched
        items = self.inner.scan()
        self.stats.n_queries += s.n_queries - q0
        self.stats.n_values += s.n_values - n0
        self.stats.bytes_fetched += s.bytes_fetched - b0
        return items

    def __contains__(self, key: str) -> bool:
        if key in self._probation or key in self._protected:
            return True
        return key in self.inner

    # ---------------------------------------------------------- write path

    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        if not items:          # PR-2 convention: no round trip, stats untouched
            return
        # Drop-before-write: if the backend applies partially and raises,
        # the cache is cold for those keys, never stale.  Previously-cached
        # keys are re-admitted after the ack (write-through) — proven-hot,
        # so they bypass the admission comparison via force.
        was_cached = {k for k, _ in items
                      if k in self._probation or k in self._protected}
        if was_cached:
            self.invalidate(was_cached)
        s = self.inner.stats
        p0, v0, b0 = s.n_put_queries, s.n_values_put, s.bytes_stored
        self.inner.multiput(items)
        self.stats.n_put_queries += s.n_put_queries - p0
        self.stats.n_values_put += s.n_values_put - v0
        self.stats.bytes_stored += s.bytes_stored - b0
        for k, v in items:
            if k in was_cached:
                self._force_admit(k, v)
                self.n_write_through += 1

    def _force_admit(self, key: str, value: bytes) -> None:
        """Write-through re-admission: skip the cost comparison (the entry
        already earned its place) but still respect the byte budget."""
        size = self._charge(key, value)
        if size > self.cache_bytes:
            return
        need = self._cached_bytes + size - self.cache_bytes
        if need > 0:
            self._evict(need)
        self._probation[key] = value
        self._cached_bytes += size

    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def multidelete(self, keys: Sequence[str]) -> None:
        if not keys:           # PR-2 convention: no round trip, stats untouched
            return
        self.invalidate(keys)  # drop first — same partial-failure argument
        s = self.inner.stats
        d0, k0 = s.n_delete_queries, s.n_keys_deleted
        self.inner.multidelete(keys)
        self.stats.n_delete_queries += s.n_delete_queries - d0
        self.stats.n_keys_deleted += s.n_keys_deleted - k0

    def delete(self, key: str) -> None:
        self.multidelete([key])

    # ------------------------------------------------------------ reporting

    def total_stored_bytes(self) -> int:
        inner_total = getattr(self.inner, "total_stored_bytes", None)
        return inner_total() if callable(inner_total) else 0

    def cache_report(self) -> Dict[str, float]:
        """Hit-rate / occupancy report (surfaced by ``rs.cache_stats()``)."""
        h, m = self.stats.n_cache_hits, self.stats.n_cache_misses
        return {
            "cache_bytes": self.cache_bytes,
            "cached_bytes": self._cached_bytes,
            "n_entries": self.n_entries,
            "n_probation": len(self._probation),
            "n_protected": len(self._protected),
            "n_cache_hits": h,
            "n_cache_misses": m,
            "hit_rate": h / (h + m) if (h + m) else 0.0,
            "bytes_served_from_cache": self.stats.bytes_served_from_cache,
            "n_evictions": self.n_evictions,
            "n_admit_rejected": self.n_admit_rejected,
            "n_invalidations": self.n_invalidations,
            "n_write_through": self.n_write_through,
            "layout_epoch": self.layout_epoch,
        }
