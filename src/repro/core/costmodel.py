"""Analytical cost model (Table 1).

Closed-form storage / full-version / point-query costs for the four baseline
schemes under the paper's simplifying assumptions: a chain of ``n`` versions,
``m_v`` records per version, update fraction ``d``, compression ratio ``c``,
record size ``s``, chunk size ``s_c``.  ``bench_table1`` checks these against
the instrumented system.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# §2.3 Cassandra-like request pricing: every batched round trip pays a fixed
# per-request overhead, every byte pays transfer time.  These two constants
# are THE system-wide simulated-cost calibration — KVSStats.simulated_seconds,
# the compaction trigger, and the chunk cache's admission rule all price
# traffic with them, so "is it worth a round trip?" means the same thing on
# every layer.
PER_QUERY_S = 5e-4
BANDWIDTH_BPS = 200e6


def fetch_seconds(n_queries: float, n_bytes: float,
                  per_query_s: float = PER_QUERY_S,
                  bandwidth_Bps: float = BANDWIDTH_BPS) -> float:
    """Simulated cost of fetching ``n_bytes`` in ``n_queries`` round trips —
    the Table-1 query-cost kernel (overhead + transfer) in one place."""
    return n_queries * per_query_s + n_bytes / bandwidth_Bps


@dataclass(frozen=True)
class Workload:
    n: int          # versions (chain)
    m_v: int        # records per version
    d: float        # fraction updated per version
    c: float        # compression ratio (c ≤ 1)
    s: float        # record size (bytes)
    s_c: float      # chunk size (bytes)


def independent_chunking(w: Workload) -> Dict[str, float]:
    """Every version stored independently, records packed into chunks."""
    return {
        "storage": w.n * w.m_v * w.s,
        "version_bytes": w.m_v * w.s,
        "version_queries": w.m_v * w.s / w.s_c,
        "point_bytes": w.s_c,
        "point_queries": 1,
    }


def delta(w: Workload) -> Dict[str, float]:
    return {
        "storage": w.m_v * w.s + w.c * w.d * (w.n - 1) * w.m_v * w.s,
        "version_bytes": w.m_v * w.s + w.c * w.d * (w.n - 1) * w.m_v * w.s / 2,
        "version_queries": w.n / 2,
        "point_bytes": w.m_v * w.s + w.c * w.d * (w.n - 1) * w.m_v * w.s / 2,
        "point_queries": w.n / 2,
    }


def subchunk(w: Workload) -> Dict[str, float]:
    return {
        "storage": w.m_v * w.s + w.c * w.d * (w.n - 1) * w.m_v * w.s,
        "version_bytes": w.m_v * (w.s + w.c * w.d * (w.n - 1) * w.s),
        "version_queries": w.m_v,
        "point_bytes": w.s + w.c * w.d * (w.n - 1) * w.s,
        "point_queries": 1,
    }


def single_address(w: Workload) -> Dict[str, float]:
    return {
        "storage": w.m_v * w.s + w.d * (w.n - 1) * w.m_v * w.s,
        "version_bytes": w.m_v * w.s,
        "version_queries": w.m_v * w.s / w.s,   # = m_v gets
        "point_bytes": w.s,
        "point_queries": 1,
    }


def rstore(w: Workload, span_factor: float = 1.0) -> Dict[str, float]:
    """RStore with dedupe + chunking: storage ≈ unique bytes; a version
    touches ≈ span_factor × (version bytes / chunk size) chunks (span_factor
    ≥ 1 measures partitioning quality — 1 is the information-theoretic
    floor)."""
    unique = w.m_v * w.s + w.d * (w.n - 1) * w.m_v * w.s
    vq = span_factor * w.m_v * w.s / w.s_c
    return {
        "storage": unique,
        "version_bytes": span_factor * w.m_v * w.s,
        "version_queries": vq,
        "point_bytes": w.s_c,
        "point_queries": 1,
    }


MODELS = {
    "independent_chunking": independent_chunking,
    "delta": delta,
    "subchunk": subchunk,
    "single_address": single_address,
    "rstore": rstore,
}
