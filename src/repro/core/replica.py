"""Replication & fault tolerance: replica groups over the Backend protocol.

The paper inherits replication and availability from Cassandra (§2.4 — RStore
"assumes only get/multiget" of a distributed KV store that is itself
replicated and fault tolerant).  Our :class:`~repro.core.kvs.ShardedKVS`
router had neither: one lost or flaky shard killed every snapshot read, group
commit, and compaction pass.  This module supplies the missing layer, in the
regime the multi-version coding line of work studies (Wang & Cadambe;
Ali & Cadambe — serving consistent versioned data from servers that fail and
lag):

- An error taxonomy rooted at :class:`BackendUnavailable`, distinguishing
  *recoverable* faults (:class:`TransientBackendError`,
  :class:`BackendTimeout` — retry) from *hard* ones (:class:`ShardDown` —
  fail over) and *write-path* ones (:class:`QuorumLost` — the group could
  not ack).  Crucially distinct from ``KeyError``: a missing key is a
  data-level miss and must never trigger a failover.

- :class:`FaultInjectingKVS`, a Backend wrapper with a deterministic seeded
  fault schedule (transient errors, simulated timeouts, hard ``kill()``)
  so every degraded-mode path is testable and byte-reproducible.

- :class:`RetryPolicy`, capped exponential backoff with deterministic
  jitter.  Nothing sleeps: the backoff the retries *would* have slept is
  accumulated in ``KVSStats.simulated_backoff_seconds`` (the same simulated-
  time convention as ``simulated_seconds``), alongside ``n_retries`` and
  ``n_failovers``.

- :class:`ReplicatedKVS`, an N-way replica group implementing the full
  Backend protocol.  Writes fan out to all live replicas with a write-ack
  quorum (default 1 — Cassandra consistency ONE, availability-first, so an
  R=2 group survives one death).  Reads go to one preferred replica and
  fail over per batch to the next on error — a failed-over batch costs at
  most one extra round trip, and a replica seen hard-down is skipped at
  zero cost until recovered.  Replicas that miss writes while unreachable
  accumulate a repair log that read-repair backfills before the replica
  serves again.

- :class:`RecoveryManager`, which ``rebuild()``\\ s a lost replica from
  survivors in O(1) round trips per surviving peer (one ``scan`` of one
  survivor + a bounded constant on the target), clearing the repair log
  and restoring the replica to the read rotation.

Composed under the hash router (``make_sharded_backend(...,
replication_factor=R)`` in :mod:`repro.launch.mesh`), the read session
(:mod:`repro.core.api`), group flush (:mod:`repro.core.ingest`), and
compaction GC (:mod:`repro.core.compact`) all survive a replica death
mid-workload unchanged — the group absorbs the fault below the router.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .kvs import Backend, KVSStats


# ------------------------------------------------------------ error taxonomy
class BackendUnavailable(RuntimeError):
    """A backend (or a whole replica group) could not serve the request.

    Root of the fault taxonomy.  Deliberately disjoint from ``KeyError``:
    "missing key" is an answer, "shard down" is not — failover logic retries
    or re-routes only the latter."""


class TransientBackendError(BackendUnavailable):
    """Recoverable blip (dropped connection, leader election, overload
    shedding).  The request was NOT applied; retrying is safe."""


class BackendTimeout(BackendUnavailable):
    """The request timed out.  A timed-out *write* may or may not have been
    applied (the ack was lost, not necessarily the write) — retries must be
    idempotent, which ``multiput`` is."""


class ShardDown(BackendUnavailable):
    """Hard failure: the shard is gone until explicitly recovered.  Retrying
    the same replica is pointless; fail over instead."""


class QuorumLost(BackendUnavailable):
    """A replicated write could not reach its write-ack quorum."""


# ------------------------------------------------------------- retry policy
@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``call(fn, stats)`` retries ``fn`` on recoverable faults
    (:class:`TransientBackendError` / :class:`BackendTimeout`) up to
    ``max_retries`` times; :class:`ShardDown` propagates immediately (the
    caller's failover concern, not a retry concern).  No wall-clock sleep
    happens: each retry's backoff is added to
    ``stats.simulated_backoff_seconds`` and counted in ``stats.n_retries``,
    keeping the whole fault path deterministic and fast under test.

    Jitter is derived from ``crc32(seed, attempt)`` — same policy, same
    attempt, same delay, every run (the §2.3 simulated-cost discipline
    applied to failure handling)."""

    max_retries: int = 4
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter_frac: float = 0.1
    seed: int = 0

    def backoff(self, attempt: int) -> float:
        """Simulated delay before retry ``attempt`` (1-based): capped
        exponential, jittered deterministically within ±``jitter_frac``."""
        raw = min(self.max_delay_s,
                  self.base_delay_s * self.multiplier ** (attempt - 1))
        u = zlib.crc32(f"{self.seed}:{attempt}".encode()) / 2**32
        return raw * (1.0 - self.jitter_frac + 2.0 * self.jitter_frac * u)

    def call(self, fn: Callable, stats: Optional[KVSStats] = None):
        attempt = 0
        while True:
            try:
                return fn()
            except ShardDown:
                raise
            except BackendUnavailable:
                attempt += 1
                if attempt > self.max_retries:
                    raise
                if stats is not None:
                    stats.n_retries += 1
                    stats.simulated_backoff_seconds += self.backoff(attempt)


# ---------------------------------------------------------- fault injection
class FaultInjectingKVS:
    """Backend wrapper with a deterministic seeded fault schedule.

    Each data op draws from a seeded stream: with probability ``p_transient``
    it raises :class:`TransientBackendError` *before* touching the inner
    backend; with probability ``p_timeout`` it simulates a lost ack —
    reads and (non-idempotent) deletes raise before applying, while
    ``multiput`` applies first and *then* raises :class:`BackendTimeout`,
    so retry paths are exercised against the ambiguous-write case.  At most
    ``max_consecutive_faults`` faults fire in a row, so any retry loop with
    ``max_retries >= max_consecutive_faults`` is guaranteed to converge —
    the property tests lean on that bound.

    ``kill()`` takes the shard hard-down (every op raises
    :class:`ShardDown`) until ``revive()``; a revived shard answers again
    but may be arbitrarily stale — that's :class:`RecoveryManager`'s
    problem.  ``stats`` delegates to the inner backend so round-trip
    accounting sees through the wrapper."""

    def __init__(self, inner: Backend, seed: int = 0,
                 p_transient: float = 0.0, p_timeout: float = 0.0,
                 max_consecutive_faults: int = 2) -> None:
        self.inner = inner
        self.seed = int(seed)
        self.p_transient = float(p_transient)
        self.p_timeout = float(p_timeout)
        self.max_consecutive_faults = int(max_consecutive_faults)
        self._rng = np.random.default_rng(self.seed)
        self._down = False
        self._consecutive = 0
        self._forced: List[str] = []    # schedule_faults() queue, FIFO
        self.n_transient_injected = 0
        self.n_timeouts_injected = 0
        self.n_down_rejections = 0

    @property
    def stats(self) -> KVSStats:
        return self.inner.stats

    # ------------------------------------------------------------- schedule
    def kill(self) -> None:
        """Hard shard-down: every subsequent op raises ShardDown."""
        self._down = True

    def revive(self) -> None:
        """The shard answers again — with whatever (stale) data it has."""
        self._down = False

    @property
    def is_down(self) -> bool:
        return self._down

    def schedule_faults(self, kinds: Sequence[str]) -> None:
        """Deterministic fault queue for interleaving tests: the next
        ``len(kinds)`` data ops consume these verbatim (``"transient"`` /
        ``"timeout"`` / ``"ok"``) instead of drawing from the seeded
        probability stream.  The ``max_consecutive_faults`` bound does
        NOT apply to scheduled faults — an explicit schedule is the
        test's own contract; pair it with a retry budget that covers it."""
        kinds = list(kinds)
        bad = set(kinds) - {"transient", "timeout", "ok"}
        if bad:
            raise ValueError(f"unknown fault kind(s) {sorted(bad)}; "
                             "expected 'transient' | 'timeout' | 'ok'")
        self._forced.extend(kinds)

    def _next_fault(self) -> Optional[str]:
        if self._down:
            self.n_down_rejections += 1
            raise ShardDown(f"shard killed (seed={self.seed})")
        if self._forced:
            kind = self._forced.pop(0)
            if kind == "transient":
                self.n_transient_injected += 1
                return "transient"
            if kind == "timeout":
                self.n_timeouts_injected += 1
                return "timeout"
            return None
        if self.p_transient <= 0.0 and self.p_timeout <= 0.0:
            return None
        u = float(self._rng.random())
        if self._consecutive >= self.max_consecutive_faults:
            self._consecutive = 0          # bounded: force a success
            return None
        if u < self.p_transient:
            self._consecutive += 1
            self.n_transient_injected += 1
            return "transient"
        if u < self.p_transient + self.p_timeout:
            self._consecutive += 1
            self.n_timeouts_injected += 1
            return "timeout"
        self._consecutive = 0
        return None

    def _raise_pre(self, fault: Optional[str]) -> None:
        if fault == "transient":
            raise TransientBackendError(f"injected transient (seed={self.seed})")
        if fault == "timeout":
            raise BackendTimeout(f"injected timeout (seed={self.seed})")

    # ---------------------------------------------------------------- reads
    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        self._raise_pre(self._next_fault())
        return self.inner.multiget(keys)

    def get(self, key: str) -> bytes:
        return self.multiget([key])[0]

    def scan(self) -> List[Tuple[str, bytes]]:
        self._raise_pre(self._next_fault())
        return self.inner.scan()

    # --------------------------------------------------------------- writes
    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        fault = self._next_fault()
        if fault == "transient":           # not applied: retry is a clean redo
            raise TransientBackendError(
                f"injected transient (seed={self.seed})")
        self.inner.multiput(items)
        if fault == "timeout":             # applied, ack lost: retry re-puts
            raise BackendTimeout(f"injected timeout (seed={self.seed})")

    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def multidelete(self, keys: Sequence[str]) -> None:
        # deletes are not idempotent (absent keys raise), so both fault
        # kinds fire before applying
        self._raise_pre(self._next_fault())
        self.inner.multidelete(keys)

    def delete(self, key: str) -> None:
        self.multidelete([key])

    # ----------------------------------------------------------------- misc
    def __contains__(self, key: str) -> bool:
        if self._down:
            self.n_down_rejections += 1
            raise ShardDown(f"shard killed (seed={self.seed})")
        return key in self.inner

    def total_stored_bytes(self) -> int:
        if self._down:
            self.n_down_rejections += 1
            raise ShardDown(f"shard killed (seed={self.seed})")
        return self.inner.total_stored_bytes()  # type: ignore[attr-defined]


# ------------------------------------------------------------ replica group
class ReplicatedKVS:
    """N-way replica group implementing the full Backend protocol.

    **Writes** (``multiput``/``multidelete``) fan out to every live replica;
    ``write_quorum`` successful acks are required (default 1 — Cassandra
    consistency ONE: an R=2 group keeps accepting writes with one replica
    dead).  A replica that misses a write — hard-down, or a live replica
    whose retries ran out — gets the miss recorded in its *repair log*
    (key → value, or a ``None`` tombstone for a missed delete), so the group
    always knows exactly what each replica lacks.

    **Reads** (``multiget``/``get``/``scan``) go to one *preferred* replica.
    If its repair log is non-empty it is backfilled first (read-repair), so
    a replica never serves stale data.  On :class:`ShardDown` the replica is
    marked down — skipped at zero cost by every later op — and the read
    fails over to the next live replica: a failed-over batch costs at most
    ONE extra round trip (``stats.n_failovers`` counts the hops), and
    subsequent batches pay zero extra.  ``KeyError`` is *not* a failure:
    a missing key propagates without failover.

    ``stats`` counts group-level traffic: one logical write round trip per
    fan-out (replication is parallel), read round trips = attempts actually
    made (1 + failover hops).  Per-replica counters stay on the replicas.
    """

    def __init__(self, replicas: Sequence[Backend], write_quorum: int = 1,
                 retry: Optional[RetryPolicy] = None) -> None:
        if not replicas:
            raise ValueError("ReplicatedKVS needs at least one replica")
        self.replicas: List[Backend] = list(replicas)
        if not (1 <= int(write_quorum) <= len(self.replicas)):
            raise ValueError(
                f"write_quorum must be in [1, {len(self.replicas)}]")
        self.write_quorum = int(write_quorum)
        self.retry = retry or RetryPolicy()
        self.stats = KVSStats()
        self._live: List[bool] = [True] * len(self.replicas)
        self._preferred = 0
        # per-replica repair log: key -> bytes (missed put) | None (missed
        # delete).  Invariant: a replica was in sync when it last went
        # unreachable, so log ∪ its stored state reconstructs the truth.
        self._repair: List[Dict[str, Optional[bytes]]] = [
            {} for _ in self.replicas]

    # ------------------------------------------------------------ liveness
    @property
    def live(self) -> Tuple[bool, ...]:
        return tuple(self._live)

    @property
    def preferred(self) -> int:
        return self._preferred

    def n_live(self) -> int:
        return sum(self._live)

    def mark_down(self, i: int) -> None:
        self._live[i] = False
        if self._preferred == i and any(self._live):
            self._preferred = min(j for j, lv in enumerate(self._live) if lv)

    def mark_live(self, i: int) -> None:
        """Return replica ``i`` to the rotation (its repair log, if any,
        is backfilled before it serves a read).  Preference returns to the
        lowest-index live replica — deterministic read placement."""
        self._live[i] = True
        self._preferred = min(j for j, lv in enumerate(self._live) if lv)

    # -------------------------------------------------------------- repair
    def pending_repairs(self, i: int) -> int:
        return len(self._repair[i])

    def _flush_repair(self, i: int) -> None:
        """Backfill replica ``i``'s missed writes (read-repair).  Applies
        missed puts, then missed deletes — filtered to keys the replica
        actually holds, because a put-then-delete missed entirely leaves a
        tombstone for a key the replica never saw."""
        rep = self._repair[i]
        if not rep:
            return
        r = self.replicas[i]
        puts = [(k, v) for k, v in rep.items() if v is not None]
        if puts:
            self.retry.call(lambda: r.multiput(puts), self.stats)
            for k, _ in puts:
                del rep[k]
        tombs = [k for k, v in rep.items() if v is None]
        dels = [k for k in tombs if k in r]
        if dels:
            self.retry.call(lambda: r.multidelete(dels), self.stats)
        for k in tombs:
            del rep[k]

    def _record_miss_put(self, i: int, items: Sequence[Tuple[str, bytes]]) -> None:
        rep = self._repair[i]
        for k, v in items:
            rep[k] = v

    def _record_miss_delete(self, i: int, keys: Sequence[str]) -> None:
        rep = self._repair[i]
        for k in keys:
            rep[k] = None

    # ---------------------------------------------------------------- reads
    def _read(self, op: Callable[[Backend], object]) -> Tuple[object, int]:
        """Run ``op`` against the preferred replica, failing over per batch.
        Returns (result, attempts).  KeyError propagates untouched — a miss
        is an answer, not a fault."""
        n = len(self.replicas)
        attempts = 0
        last: Optional[BackendUnavailable] = None
        # capture the rotation up front: mark_down() moves _preferred, and
        # the failover order must not chase it mid-loop
        order = [(self._preferred + j) % n for j in range(n)]
        for i in order:
            if not self._live[i]:
                continue                    # known-down: zero-cost skip
            r = self.replicas[i]
            attempts += 1
            try:
                self._flush_repair(i)       # read-repair before serving
                out = self.retry.call(lambda: op(r), self.stats)
            except ShardDown as e:
                self.mark_down(i)
                self.stats.n_failovers += 1
                last = e
                continue
            except BackendUnavailable as e:
                self.stats.n_failovers += 1  # flaky but not hard-down
                last = e
                continue
            self._preferred = i
            return out, attempts
        raise last or ShardDown(
            f"all {n} replicas of the group are down")

    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        if not keys:
            return []
        keys = list(keys)
        vals, attempts = self._read(lambda r: r.multiget(keys))
        self.stats.n_queries += attempts
        self.stats.n_values += len(vals)            # type: ignore[arg-type]
        self.stats.bytes_fetched += sum(len(v) for v in vals)  # type: ignore
        return vals                                  # type: ignore[return-value]

    def get(self, key: str) -> bytes:
        return self.multiget([key])[0]

    def scan(self) -> List[Tuple[str, bytes]]:
        items, attempts = self._read(lambda r: r.scan())
        self.stats.n_queries += attempts
        self.stats.n_values += len(items)           # type: ignore[arg-type]
        self.stats.bytes_fetched += sum(len(v) for _, v in items)  # type: ignore
        return items                                 # type: ignore[return-value]

    def multiget_naive(self, keys: Sequence[str]) -> List[bytes]:
        return [self.get(k) for k in keys]

    # --------------------------------------------------------------- writes
    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        if not items:
            return
        items = list(items)
        acks = 0
        for i, r in enumerate(self.replicas):
            if not self._live[i]:
                self._record_miss_put(i, items)
                continue
            try:
                self._flush_repair(i)       # missed writes land first, in order
                self.retry.call(lambda r=r: r.multiput(items), self.stats)
                acks += 1
            except ShardDown:
                self.mark_down(i)
                self._record_miss_put(i, items)
            except BackendUnavailable:
                self._record_miss_put(i, items)
        if acks < self.write_quorum:
            raise QuorumLost(
                f"multiput acked by {acks}/{len(self.replicas)} replicas, "
                f"quorum is {self.write_quorum}")
        self.stats.n_put_queries += 1       # one logical (parallel) round trip
        self.stats.n_values_put += len(items)
        self.stats.bytes_stored += sum(len(v) for _, v in items)

    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def multidelete(self, keys: Sequence[str]) -> None:
        if not keys:
            return
        keys = list(keys)
        acks = 0
        for i, r in enumerate(self.replicas):
            if not self._live[i]:
                self._record_miss_delete(i, keys)
                continue
            try:
                self._flush_repair(i)
                self.retry.call(lambda r=r: r.multidelete(keys), self.stats)
                acks += 1
            except ShardDown:
                self.mark_down(i)
                self._record_miss_delete(i, keys)
            except BackendUnavailable:
                self._record_miss_delete(i, keys)
        if acks < self.write_quorum:
            raise QuorumLost(
                f"multidelete acked by {acks}/{len(self.replicas)} replicas, "
                f"quorum is {self.write_quorum}")
        self.stats.n_delete_queries += 1
        self.stats.n_keys_deleted += len(keys)

    def delete(self, key: str) -> None:
        self.multidelete([key])

    # ----------------------------------------------------------------- misc
    def __contains__(self, key: str) -> bool:
        n = len(self.replicas)
        order = [(self._preferred + j) % n for j in range(n)]
        for i in order:
            if not self._live[i]:
                continue
            rep = self._repair[i]
            if key in rep:                  # pending state is the truth
                return rep[key] is not None
            try:
                return key in self.replicas[i]
            except ShardDown:
                self.mark_down(i)
            except BackendUnavailable:
                continue
        raise ShardDown(f"all {n} replicas of the group are down")

    def total_stored_bytes(self) -> int:
        """Logical bytes (one copy), from the first answering live replica.
        Metrics-path: no stats, no failover accounting, no repair flush."""
        for j in range(len(self.replicas)):
            i = (self._preferred + j) % len(self.replicas)
            if not self._live[i]:
                continue
            try:
                return self.replicas[i].total_stored_bytes()  # type: ignore
            except BackendUnavailable:
                continue
        raise ShardDown("all replicas of the group are down")

    def replica_stats(self) -> List[KVSStats]:
        return [r.stats for r in self.replicas]


# ---------------------------------------------------------------- recovery
@dataclass
class RecoveryReport:
    """What one :meth:`RecoveryManager.rebuild` did, with its round-trip
    budget: one ``scan`` of one surviving peer, plus a constant (≤3 ops)
    on the target."""

    shard: Optional[int]
    replica: int
    source: int
    keys_copied: int = 0
    bytes_copied: int = 0
    stale_keys_deleted: int = 0
    read_round_trips: int = 0
    write_round_trips: int = 0
    delete_round_trips: int = 0

    @property
    def round_trips(self) -> int:
        return (self.read_round_trips + self.write_round_trips
                + self.delete_round_trips)


class RecoveryManager:
    """Rebuilds lost replicas from survivors.

    Wraps either a single :class:`ReplicatedKVS` group or a
    :class:`~repro.core.kvs.ShardedKVS` router whose shards are replica
    groups.  ``rebuild(replica, shard=...)`` reconstructs one replica:

    1. pick the first live survivor, flush its repair log (its copy is then
       authoritative) and ``scan`` it — ONE read round trip on that peer;
    2. ``scan`` the target (revived-but-stale, or a fresh replacement),
       delete its stale keys, and copy every missing/changed value in one
       ``multiput`` — at most three ops on the target, never more;
    3. clear the target's repair log and return it to the read rotation
       (preference returns to the lowest live index, so a rebuilt replica 0
       serves reads again immediately).

    The target must be reachable (revive it, or swap in a fresh backend at
    ``group.replicas[i]``) — rebuilding a shard that still raises
    :class:`ShardDown` fails loudly."""

    def __init__(self, backend) -> None:
        self.backend = backend

    # ------------------------------------------------------------- helpers
    def _group(self, shard: Optional[int]) -> ReplicatedKVS:
        if isinstance(self.backend, ReplicatedKVS):
            if shard not in (None, 0):
                raise ValueError("backend is a single replica group; "
                                 "shard must be None")
            return self.backend
        shards = getattr(self.backend, "shards", None)
        if shards is None:
            raise TypeError("RecoveryManager needs a ReplicatedKVS or a "
                            "ShardedKVS over ReplicatedKVS groups")
        if shard is None:
            raise ValueError("backend is sharded; pass shard=<index>")
        group = shards[shard]
        if not isinstance(group, ReplicatedKVS):
            raise TypeError(f"shard {shard} is not a ReplicatedKVS")
        return group

    def groups(self) -> List[Tuple[Optional[int], ReplicatedKVS]]:
        if isinstance(self.backend, ReplicatedKVS):
            return [(None, self.backend)]
        return [(i, g) for i, g in enumerate(self.backend.shards)
                if isinstance(g, ReplicatedKVS)]

    # -------------------------------------------------------------- rebuild
    def rebuild(self, replica: int, shard: Optional[int] = None,
                ) -> RecoveryReport:
        group = self._group(shard)
        n = len(group.replicas)
        if not (0 <= replica < n):
            raise ValueError(f"replica index {replica} out of range [0,{n})")
        target = group.replicas[replica]

        # survivor selection fails over, like every read: a candidate whose
        # live flag is stale (killed since its last op) must not crash the
        # rebuild — mark it down and try the next peer.  Its copy becomes
        # authoritative only after its repair log flushes, so the flush is
        # inside the guarded attempt too.
        source = None
        want = None
        last_err: Optional[BackendUnavailable] = None
        start = group.preferred   # pin: mark_down below moves the preference
        for j in range(n):
            i = (start + j) % n
            if i == replica or not group._live[i]:
                continue
            try:
                # survivor: repair log flushed -> authoritative; ONE scan
                group._flush_repair(i)
                want = dict(group.retry.call(
                    lambda i=i: group.replicas[i].scan(), group.stats))
                source = i
                break
            except ShardDown as e:
                group.mark_down(i)
                group.stats.n_failovers += 1
                last_err = e
            except BackendUnavailable as e:
                group.stats.n_failovers += 1
                last_err = e
        if source is None:
            raise last_err or ShardDown("no live survivor to rebuild from")

        # target: diff against its (possibly stale, possibly empty) state
        have = dict(group.retry.call(lambda: target.scan(), group.stats))
        stale = [k for k in have if k not in want]
        if stale:
            group.retry.call(lambda: target.multidelete(stale), group.stats)
        to_put = [(k, v) for k, v in want.items() if have.get(k) != v]
        if to_put:
            group.retry.call(lambda: target.multiput(to_put), group.stats)

        group._repair[replica].clear()
        group.mark_live(replica)
        return RecoveryReport(
            shard=shard, replica=replica, source=source,
            keys_copied=len(to_put),
            bytes_copied=sum(len(v) for _, v in to_put),
            stale_keys_deleted=len(stale),
            read_round_trips=2,
            write_round_trips=1 if to_put else 0,
            delete_round_trips=1 if stale else 0)

    def recover_all(self) -> List[RecoveryReport]:
        """Rebuild every down replica and flush every live replica's repair
        log, leaving all groups fully replicated and in sync."""
        reports: List[RecoveryReport] = []
        for shard, group in self.groups():
            for i, lv in enumerate(group.live):
                if not lv:
                    reports.append(self.rebuild(i, shard=shard))
            for i in range(len(group.replicas)):
                if not group._live[i]:
                    continue
                try:
                    group._flush_repair(i)
                except ShardDown:
                    # stale-live replica discovered dead mid-flush: out of
                    # the rotation; its repair log is kept (flush removes
                    # ops only after they apply) for the next rebuild
                    group.mark_down(i)
                except BackendUnavailable:
                    pass  # flaky, not dead: log stays; a later flush retries
        return reports
