"""Physical chunk layout + chunk maps (§2.4).

A stored chunk holds (a) its records' payloads grouped into *sub-chunks*
(singleton sub-chunks unless §3.4 compression is enabled: records of one
primary key, connected in the version tree, XOR-delta'd against their
sub-chunk parent and zlib'd together), and (b) the chunk map ``M^{C_i}`` —
for each record, the set of versions containing it, stored as a bitmap over
version indices ("the adjacency list in each chunk map file is then converted
to a bitmap, compressed and stored in the KVS").
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import ops as kops
from .types import Partitioning
from .version_graph import VersionGraph


# ------------------------------------------------------------------ chunk map
@dataclass
class ChunkMap:
    """Per-chunk slice of the 3-D mapping M (Fig. 3): record composite keys +
    a (n_rec, W) uint32 bitmap of version-index membership."""

    cks: np.ndarray            # (n_rec,) int64 packed composite keys
    bitmap: np.ndarray         # (n_rec, W) uint32
    n_versions: int

    def records_in_version(self, vidx: int) -> np.ndarray:
        w, bit = divmod(vidx, 32)
        hit = (self.bitmap[:, w] >> np.uint32(bit)) & np.uint32(1)
        return np.flatnonzero(hit)

    def versions_of_record(self, local_idx: int) -> np.ndarray:
        row = self.bitmap[local_idx]
        out = []
        for w in range(len(row)):
            v = int(row[w])
            while v:
                b = v & -v
                out.append(w * 32 + b.bit_length() - 1)
                v ^= b
        return np.asarray([o for o in out if o < self.n_versions], dtype=np.int64)

    def to_bytes(self) -> bytes:
        raw = self.bitmap.astype("<u4").tobytes()
        comp = zlib.compress(raw, level=6)
        head = struct.pack("<IIII", len(self.cks), self.bitmap.shape[1],
                           self.n_versions, len(comp))
        return head + self.cks.astype("<i8").tobytes() + comp

    @staticmethod
    def from_bytes(buf: bytes) -> "ChunkMap":
        n_rec, w, n_ver, clen = struct.unpack_from("<IIII", buf, 0)
        off = 16
        cks = np.frombuffer(buf, dtype="<i8", count=n_rec, offset=off).astype(np.int64)
        off += n_rec * 8
        raw = zlib.decompress(buf[off:off + clen])
        bitmap = np.frombuffer(raw, dtype="<u4").reshape(n_rec, w).astype(np.uint32)
        return ChunkMap(cks=cks, bitmap=bitmap, n_versions=n_ver)


# --------------------------------------------------------------- stored chunk
@dataclass
class SubChunkBlob:
    """One compressed sub-chunk: local record indices (first = raw base, the
    rest XOR-delta'd against their sub-chunk tree parent) + payload blob."""

    local_ids: np.ndarray      # int32 local record indices, tree (BFS) order
    parent_pos: np.ndarray     # int32: position *within sub-chunk* of each
    #                            record's delta parent (-1 = stored raw)
    lengths: np.ndarray        # int32 true payload lengths
    blob: bytes                # zlib(concat of raw-or-delta payloads)


@dataclass
class StoredChunk:
    chunk_id: int
    cks: np.ndarray                      # (n_rec,) packed composite keys
    subchunks: List[SubChunkBlob]
    raw_bytes: int = 0                   # un-encoded payload bytes
    stored_bytes: int = 0                # encoded (what the KVS holds)
    # memoized serialization: chunks are write-once, and the build paths
    # both size the encoding and stage it for the group commit
    _encoded: Optional[bytes] = field(default=None, repr=False, compare=False)

    def payloads(self) -> Dict[int, bytes]:
        """Decode every record: local index -> payload bytes."""
        out: Dict[int, bytes] = {}
        for sc in self.subchunks:
            raw = zlib.decompress(sc.blob)
            parts: List[bytes] = []
            off = 0
            dec: List[bytes] = []
            for i, ln in enumerate(sc.lengths):
                ln = int(ln)
                # deltas are stored at the max(parent,child) length
                p = int(sc.parent_pos[i])
                stored_len = ln if p < 0 else max(ln, len(dec[p]))
                piece = raw[off:off + stored_len]
                off += stored_len
                if p < 0:
                    dec.append(piece[:ln])
                else:
                    plain, _ = kops.xor_delta_bytes(
                        dec[p].ljust(stored_len, b"\0"), piece)
                    dec.append(plain[:ln])
            for li, payload in zip(sc.local_ids, dec):
                out[int(li)] = payload
        return out

    # ------------------------------------------------------------ serialization
    def to_bytes(self) -> bytes:
        if self._encoded is None:
            parts = [struct.pack("<III", self.chunk_id, len(self.cks), len(self.subchunks))]
            parts.append(self.cks.astype("<i8").tobytes())
            for sc in self.subchunks:
                parts.append(struct.pack("<II", len(sc.local_ids), len(sc.blob)))
                parts.append(sc.local_ids.astype("<i4").tobytes())
                parts.append(sc.parent_pos.astype("<i4").tobytes())
                parts.append(sc.lengths.astype("<i4").tobytes())
                parts.append(sc.blob)
            self._encoded = b"".join(parts)
        return self._encoded

    @staticmethod
    def from_bytes(buf: bytes) -> "StoredChunk":
        cid, n_rec, n_sub = struct.unpack_from("<III", buf, 0)
        off = 12
        cks = np.frombuffer(buf, dtype="<i8", count=n_rec, offset=off).astype(np.int64)
        off += 8 * n_rec
        subs = []
        for _ in range(n_sub):
            n, blen = struct.unpack_from("<II", buf, off)
            off += 8
            li = np.frombuffer(buf, dtype="<i4", count=n, offset=off).astype(np.int32)
            off += 4 * n
            pp = np.frombuffer(buf, dtype="<i4", count=n, offset=off).astype(np.int32)
            off += 4 * n
            ln = np.frombuffer(buf, dtype="<i4", count=n, offset=off).astype(np.int32)
            off += 4 * n
            blob = buf[off:off + blen]
            off += blen
            subs.append(SubChunkBlob(li, pp, ln, blob))
        sc = StoredChunk(chunk_id=cid, cks=cks, subchunks=subs)
        sc.stored_bytes = len(buf)
        sc.raw_bytes = int(sum(s.lengths.sum() for s in subs))
        return sc


# -------------------------------------------------------------------- builder
def build_chunk(graph: VersionGraph, record_ids: np.ndarray, chunk_id: int,
                vidx_of: Dict[int, int], n_versions: int,
                rec_versions_csr: Tuple[np.ndarray, np.ndarray],
                subchunk_groups: Optional[List[np.ndarray]] = None,
                compress_level: int = 6) -> Tuple[StoredChunk, ChunkMap]:
    """Assemble one physical chunk + its chunk map.

    ``subchunk_groups``: optional list of record-id arrays (each a connected
    same-primary-key group in sub-chunk tree order, §3.4); defaults to
    singleton groups.  Records absent from any group get singletons.
    """
    store = graph.store
    local_of = {int(r): i for i, r in enumerate(record_ids)}
    cks = store.cks[record_ids]

    groups: List[np.ndarray]
    if subchunk_groups is None:
        groups = [np.array([r], dtype=np.int64) for r in record_ids]
    else:
        seen = set()
        groups = []
        for grp in subchunk_groups:
            groups.append(np.asarray(grp, dtype=np.int64))
            seen.update(int(g) for g in grp)
        for r in record_ids:
            if int(r) not in seen:
                groups.append(np.array([r], dtype=np.int64))

    raw_total = 0
    subs: List[SubChunkBlob] = []
    tree_parent_rid = _subchunk_parents(graph, groups)
    for grp, parents in zip(groups, tree_parent_rid):
        local = np.array([local_of[int(r)] for r in grp], dtype=np.int32)
        lens = store.sizes[grp].astype(np.int32)
        pieces: List[bytes] = []
        payloads = [store.payload(int(r)) if store.has_payloads() else b"\0" * int(store.sizes[r])
                    for r in grp]
        raw_total += int(lens.sum())
        ppos = np.full(len(grp), -1, dtype=np.int32)
        pos_of = {int(r): i for i, r in enumerate(grp)}
        for i, r in enumerate(grp):
            par = parents[i]
            if par is None or int(par) not in pos_of:
                pieces.append(payloads[i])
            else:
                pi = pos_of[int(par)]
                ppos[i] = pi
                w = max(len(payloads[pi]), len(payloads[i]))
                delta, _ = kops.xor_delta_bytes(payloads[pi].ljust(w, b"\0"),
                                                payloads[i].ljust(w, b"\0"))
                pieces.append(delta)
        blob = zlib.compress(b"".join(pieces), level=compress_level)
        subs.append(SubChunkBlob(local_ids=local, parent_pos=ppos,
                                 lengths=lens, blob=blob))

    chunk = StoredChunk(chunk_id=chunk_id, cks=cks, subchunks=subs,
                        raw_bytes=raw_total)
    chunk.stored_bytes = len(chunk.to_bytes())

    # ---- chunk map: bitmap over version indices --------------------------
    W = (n_versions + 31) // 32
    bitmap = np.zeros((len(record_ids), W), dtype=np.uint32)
    indptr, vidxs = rec_versions_csr
    for i, r in enumerate(record_ids):
        vs = vidxs[indptr[r]:indptr[r + 1]]
        # bitwise_or.at: unbuffered — duplicate word indices must accumulate
        np.bitwise_or.at(bitmap[i], vs // 32,
                         np.uint32(1) << (vs % 32).astype(np.uint32))
    cmap = ChunkMap(cks=cks, bitmap=bitmap, n_versions=n_versions)
    return chunk, cmap


def _subchunk_parents(graph: VersionGraph, groups: List[np.ndarray]):
    """For each group, the delta-parent record id of each member (None = raw).
    Members are same-primary-key records connected in the version tree; the
    parent of record (K, Vc) is the record (K, Vp) live at the nearest proper
    ancestor of Vc — within the group, that is the group member whose origin
    version is the closest ancestor."""
    origins = graph.store.origin_versions()
    out = []
    for grp in groups:
        if len(grp) == 1:
            out.append([None])
            continue
        grp_origin = {int(origins[r]): int(r) for r in grp}
        parents: List[Optional[int]] = []
        for r in grp:
            v = int(origins[r])
            p = graph.tree_parent(v)
            found = None
            while p is not None:
                if p in grp_origin:
                    found = grp_origin[p]
                    break
                p = graph.tree_parent(p)
            parents.append(found)
        out.append(parents)
    return out
