"""Snapshot sessions: the fetch layer of the unified query planner.

RStore's core insight (§2.3–§2.4) is that few large batched fetches beat many
small ones, and that retrieval cost is governed by which chunks a plan
touches.  The logical plan IR, the physical bitmap-program compiler, and the
answer layer all live in :mod:`repro.core.plan`; this module owns the one
thing that talks to the KVS — the session pipeline:

1. **Plan** — :class:`~repro.core.plan.Planner` compiles the whole batch:
   every distinct leaf predicate contributes one bitmap row (shared across
   queries), each predicate tree becomes AND/OR instructions, and the batch
   executes ONE fused ``bitmap_vm_batch`` kernel launch.
2. **Dedupe** — candidate chunk ids are unioned across the batch; a chunk
   needed by ten queries is fetched once.  Index-only plans (``Q.count`` /
   ``Q.exists`` / ``Q.distinct``) contribute their chunk *maps* only — their
   payload blobs are never requested.
3. **Fetch** — ONE combined ``multiget`` for payloads *and* chunk maps
   (interleaved ``chunk/i``, ``map/i`` keys, then the map-only tail): a
   single backend round trip for the whole session.
4. **Answer** — :func:`repro.core.plan.answer` (the single per-kind switch)
   materializes each result from the shared fetch, post-filtering exactly
   per record; metadata-mode aggregates never touch the KVS at all.

Usage::

    snap = rs.snapshot()                 # immutable read view (no flush)
    results = snap.execute([
        Q.version(v3),
        Q.record(v3, pk=7),
        Q.range(v3, 10, 19),
        Q.evolution(7),
        Q.where(v3, "color", 2),         # needs rs.create_index("color", ...)
        Q.and_(Q.where(v3, "color", 2),  # composite: ONE kernel launch,
               Q.where_range(v3, "size", 10, 20)),   # ONE multiget
        Q.count(Q.where(v3, "color", 2)),    # index-only: zero payload fetch
        Q.distinct(v3, "color"),             # index-only
    ])
    results[0].value                     # {pk: payload, ...}
    results[0].stats                     # per-query QueryStats
    results.batch                        # batch-level QueryStats
    print(snap.explain([Q.version(v3)])[0]["plan"])   # rendered plan tree

Reads never mutate the store: ``Snapshot`` holds the flushed state and
``execute`` only touches the KVS.  ``RStore.get_*`` remain as thin wrappers
over single-query batches.

The write side mirrors this design: :class:`repro.core.ingest.WriteSession`
(``rs.writer()``) stages a wave of commits and group-flushes them through
one ``Backend.multiput`` — under :class:`repro.core.kvs.ShardedKVS` both
directions cost one round trip per shard touched, however many queries or
chunks the session carries.

Fault tolerance is below this layer: with replicated shards
(:class:`repro.core.replica.ReplicatedKVS`, via ``make_sharded_backend(...,
replication_factor=R)``) the session ``multiget`` survives a replica death
mid-workload unchanged — the group fails the batch over to a surviving
replica (at most one extra read round trip per failed-over shard batch)
and returns byte-identical results.  Only a whole shard group going down
surfaces here, as :class:`repro.core.replica.BackendUnavailable`.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from . import costmodel
from . import plan as plan_mod
from .chunkstore import ChunkMap, StoredChunk
from .index import Projections
from .kvs import Backend
from .plan import (BatchResult, ExecContext, PlannedQuery, Q, Query,
                   QueryResult, QueryStats, render_plan)
from .secondary import SecondaryIndex
from .version_graph import VersionGraph

__all__ = ["Q", "Query", "QueryStats", "QueryResult", "BatchResult",
           "Snapshot"]


# ------------------------------------------------------------------- snapshot
class Snapshot:
    """Immutable read view over the flushed store state.

    Obtained via :meth:`RStore.snapshot`.  Holds the version graph,
    projections and KVS handle as of the last flush; ``execute`` plans and
    runs a whole batch of queries against it with one KVS round trip.
    Reads never mutate the store (the seed API's implicit flush-on-read is
    gone; ``RStoreConfig.auto_flush`` keeps it for back-compat at the
    ``RStore`` facade).

    Online (k=1) flushes after the snapshot only append chunks, so the
    snapshot keeps serving its versions; a full ``build()`` (including any
    k>1 flush) repartitions storage and *invalidates* the snapshot —
    ``execute`` then raises rather than silently reading rewritten chunks.
    """

    def __init__(self, graph: VersionGraph, proj: Projections,
                 kvs: Backend, epoch: Optional[int] = None,
                 current_epoch: Optional[Callable[[], int]] = None,
                 layout_epoch: Optional[int] = None,
                 current_layout_epoch: Optional[Callable[[], int]] = None,
                 indexes: Optional[Dict[str, SecondaryIndex]] = None,
                 repin: Optional[Callable[[], tuple]] = None,
                 staleness_lag: int = 0,
                 chunk_bytes: int = 1 << 16,
                 ) -> None:
        self.graph = graph
        self.proj = proj
        self.kvs = kvs
        # async ingest (core/flusher.py): committed-but-not-durable versions
        # at snapshot time.  0 for fresh (read-your-writes) snapshots; a
        # pinned snapshot reports how far behind the durable state it runs.
        # Staged versions are invisible to it — querying one fails loudly.
        self.staleness_lag = int(staleness_lag)
        # attr -> SecondaryIndex serving Q.where / Q.where_range plans
        self.indexes: Dict[str, SecondaryIndex] = indexes or {}
        self._vidx = {v: i for i, v in enumerate(graph.versions)}
        # target chunk payload size (ingest config) — explain()'s byte model
        self._chunk_bytes = int(chunk_bytes)
        # rebuild-epoch guard: a full build() repartitions and rewrites the
        # chunk/* and map/* keys, so chunk ids planned from this snapshot's
        # projections would dereference to unrelated data.  Online (k=1)
        # flushes only append chunks and extend maps, so they don't
        # invalidate snapshots and don't bump the epoch.
        self._epoch = epoch
        self._current_epoch = current_epoch
        # layout-epoch guard: a compaction pass rewrites *some* chunks and
        # deletes their old keys, but preserves the logical content of every
        # retained version — so a stale snapshot is re-pinnable via
        # :meth:`refresh` instead of dead like after a build()
        self._layout_epoch = layout_epoch
        self._current_layout_epoch = current_layout_epoch
        self._repin = repin

    def _check_fresh(self) -> None:
        if (self._epoch is not None and self._current_epoch is not None
                and self._current_epoch() != self._epoch):
            raise RuntimeError(
                "snapshot invalidated by a full rebuild (build() or a k>1 "
                "flush repartitions chunk storage); take a new snapshot()")
        if (self._layout_epoch is not None
                and self._current_layout_epoch is not None
                and self._current_layout_epoch() != self._layout_epoch):
            raise RuntimeError(
                "a compaction pass re-partitioned chunk storage under this "
                "snapshot; call snapshot.refresh() to re-pin (compaction "
                "preserves the logical content of retained versions)")

    def refresh(self) -> "Snapshot":
        """Re-pin to the store's current physical layout after a compaction
        pass.  Compaction never changes what a retained version contains,
        so this is safe and cheap — unlike a full ``build()``, after which
        only a new ``snapshot()`` helps (and this raises)."""
        if (self._epoch is not None and self._current_epoch is not None
                and self._current_epoch() != self._epoch):
            raise RuntimeError(
                "snapshot invalidated by a full rebuild (build() or a k>1 "
                "flush repartitions chunk storage); take a new snapshot()")
        if self._repin is None:
            raise RuntimeError("snapshot is not attached to a store; "
                               "take a new snapshot()")
        pinned = self._repin()
        if len(pinned) == 3:
            self.proj, self.indexes, self._layout_epoch = pinned
        else:  # older 2-tuple repin hooks (no secondary indexes)
            self.proj, self._layout_epoch = pinned
        self._vidx = {v: i for i, v in enumerate(self.graph.versions)}
        return self

    # ---------------------------------------------------------------- plan
    def _planner(self) -> plan_mod.Planner:
        # planners are batch-scoped: leaf-row dedupe and the instruction
        # stream accumulate per plan_batch call
        return plan_mod.Planner(self.graph, self.proj, self.indexes,
                                self._vidx)

    def plan_batch(self, queries: Sequence[Query]) -> List[PlannedQuery]:
        """Physical plans (mode + candidate chunks) for a batch — every
        launch-needing query shares ONE fused bitmap-program launch."""
        return self._planner().plan_batch(list(queries))

    def plan(self, queries: Sequence[Query]) -> List[np.ndarray]:
        """Candidate chunk ids per query (the legacy entry point — now a
        thin view over :meth:`plan_batch`)."""
        return [pq.cand for pq in self.plan_batch(queries)]

    # ------------------------------------------------------------ prefetch
    @staticmethod
    def _fetch_keys(payload_ids: Iterable[int],
                    map_only_ids: Iterable[int]) -> List[str]:
        """The session's one multiget key list: interleaved payload+map keys
        first (the legacy layout, byte-compatible with existing cache
        admission), then the map-only tail for index-only plans."""
        keys = [k for c in payload_ids for k in (f"chunk/{c}", f"map/{c}")]
        keys.extend(f"map/{c}" for c in map_only_ids)
        return keys

    @staticmethod
    def _split_ids(planned: Sequence[PlannedQuery]
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Dedupe candidates across the batch into (payload ids, map-only
        ids): a chunk wanted by any fetch-mode plan gets its payload; one
        wanted only by index-only plans gets its map alone."""
        pay = [pq.cand for pq in planned if pq.needs_payload and len(pq.cand)]
        maps = [pq.cand for pq in planned
                if pq.mode == "index_only" and len(pq.cand)]
        payload_ids = (np.unique(np.concatenate(pay)) if pay
                       else np.empty(0, np.int64))
        map_ids = (np.unique(np.concatenate(maps)) if maps
                   else np.empty(0, np.int64))
        map_only = np.setdiff1d(map_ids, payload_ids, assume_unique=True)
        return payload_ids, map_only

    def prefetch(self, queries: Sequence[Query]) -> Dict[str, int]:
        """Warm the chunk cache with everything ``queries`` would fetch.

        A no-op (``{"warmed_keys": 0, ...}``) unless the snapshot's KVS is a
        :class:`~repro.core.cache.CachingKVS` layer.  The fill is a normal
        read-through ``multiget`` — already-cached keys cost nothing, misses
        arrive in ONE round trip (per shard) and pass the admission rule —
        so a subsequent ``execute`` of the same queries takes 0 backend read
        round trips.  Index-only plans warm their chunk maps only.
        """
        self._check_fresh()
        if not getattr(self.kvs, "is_cache", False):
            return {"warmed_keys": 0, "round_trips": 0, "cache": 0}
        payload_ids, map_only = self._split_ids(self.plan_batch(queries))
        return self._warm(self._fetch_keys(
            (int(c) for c in payload_ids), (int(c) for c in map_only)))

    def prefetch_evolution(self, pk: int, lineage_versions: int = 4
                           ) -> Dict[str, int]:
        """Warm the cache for ``Q.evolution(pk)`` by walking VersionGraph
        paths.

        The base warm set is the evolution query's *planned* candidates
        (pk's key posting list, minus retention-pruned dead chunks) —
        exactly what the query fetches, so it runs with 0 backend read
        round trips afterwards.  On top, the version-tree paths root→leaf
        are walked to recover the lineage of versions where ``pk`` actually
        changed (its record copies name their origin versions), and the
        newest ``lineage_versions`` of those get their version posting
        lists warmed too — an evolution read is typically followed by
        version/record reads at the versions where the record changed.
        """
        self._check_fresh()
        if not getattr(self.kvs, "is_cache", False):
            return {"warmed_keys": 0, "round_trips": 0, "cache": 0}
        (pq,) = self.plan_batch([Q.evolution(pk)])
        cids = {int(c) for c in pq.cand}

        # lineage walk: origins of pk's copies, ordered along tree paths
        store = self.graph.store
        rids = np.flatnonzero(store.keys() == pk)
        origin_set = {int(o) for o in store.origin_versions()[rids]}
        lineage: List[int] = []
        seen: set = set()
        for leaf in self.graph.leaves():
            if self.graph.is_retired(leaf):
                continue
            # path_to_root is leaf→root; reverse for chronological order
            for v in reversed(self.graph.path_to_root(leaf)):
                if v in origin_set and v not in seen:
                    seen.add(v)
                    lineage.append(v)
        for v in lineage[-lineage_versions:]:
            vc = self.proj.version_chunks.get(v)
            if vc is not None:
                cids.update(int(c) for c in vc)
        return self._warm(self._fetch_keys(sorted(cids), ()))

    def _warm(self, keys: List[str]) -> Dict[str, int]:
        s = self.kvs.stats
        q0, h0 = s.n_queries, s.n_cache_hits
        if keys:
            self.kvs.multiget(keys)
        return {"warmed_keys": len(keys),
                "round_trips": s.n_queries - q0,
                "already_cached": s.n_cache_hits - h0,
                "cache": 1}

    # ------------------------------------------------------------- execute
    def execute(self, queries: Sequence[Query]) -> BatchResult:
        """Plan → dedupe → ONE interleaved multiget → answer."""
        self._check_fresh()
        planned = self.plan_batch(queries)

        payload_ids, map_only = self._split_ids(planned)
        batch = QueryStats()
        batch.chunks_fetched = len(payload_ids) + len(map_only)
        batch.payload_chunks_fetched = len(payload_ids)
        fetched: Dict[int, Tuple[Optional[StoredChunk], ChunkMap, int]] = {}
        keys = self._fetch_keys((int(c) for c in payload_ids),
                                (int(c) for c in map_only))
        if keys:
            q0 = self.kvs.stats.n_queries
            b0 = self.kvs.stats.bytes_fetched
            h0 = self.kvs.stats.n_cache_hits
            c0 = self.kvs.stats.bytes_served_from_cache
            # interleaved chunk/map keys: payloads + maps in ONE round trip.
            # Under a CachingKVS the hit/miss partition happens inside this
            # multiget — cached keys are served from memory and ONE inner
            # fetch covers the misses, so kvs_queries is 0 on a warm cache.
            blobs = self.kvs.multiget(keys)
            batch.kvs_queries = self.kvs.stats.n_queries - q0
            batch.bytes_fetched = self.kvs.stats.bytes_fetched - b0
            batch.cache_hits = self.kvs.stats.n_cache_hits - h0
            batch.bytes_from_cache = self.kvs.stats.bytes_served_from_cache - c0
            # payload round trips: the multiget carried chunk/* keys iff any
            # fetch-mode plan had candidates — index-only/metadata batches
            # report 0 here even though their maps cost a round trip
            batch.payload_round_trips = (batch.kvs_queries
                                         if len(payload_ids) else 0)
            for j, cid in enumerate(payload_ids):
                cb, mb = blobs[2 * j], blobs[2 * j + 1]
                fetched[int(cid)] = (StoredChunk.from_bytes(cb),
                                     ChunkMap.from_bytes(mb),
                                     len(cb) + len(mb))
            base = 2 * len(payload_ids)
            for j, cid in enumerate(map_only):
                mb = blobs[base + j]
                fetched[int(cid)] = (None, ChunkMap.from_bytes(mb), len(mb))

        ctx = self._exec_context(fetched)
        results: List[QueryResult] = []
        for pq in planned:
            stats = QueryStats(
                chunks_fetched=len(pq.cand),
                bytes_fetched=sum(fetched[int(c)][2] for c in pq.cand),
                kvs_queries=batch.kvs_queries if len(pq.cand) else 0,
                payload_chunks_fetched=(len(pq.cand) if pq.needs_payload
                                        else 0),
                payload_round_trips=(batch.payload_round_trips
                                     if pq.needs_payload and len(pq.cand)
                                     else 0),
            )
            value = plan_mod.answer(pq, ctx, stats)
            batch.records_returned += stats.records_returned
            batch.irrelevant_chunks += stats.irrelevant_chunks
            results.append(QueryResult(query=pq.query, value=value,
                                       stats=stats))
        return BatchResult(results, batch)

    def _exec_context(self, fetched: Dict[int, Tuple[Optional[StoredChunk],
                                                     ChunkMap, int]]
                      ) -> ExecContext:
        # retention-aware evolution: with retired versions around, a kept
        # chunk may still hold record copies reachable from no retained
        # version; their chunk-map bitmap rows tell us (no retained bit set)
        # and they are filtered out of Q3 results
        self._retained_bits = None
        if self.graph.has_retired():
            order = self.graph.versions
            idx = np.asarray([i for i, v in enumerate(order)
                              if not self.graph.is_retired(v)], dtype=np.int64)
            bits = np.zeros((len(order) + 31) // 32, dtype=np.uint32)
            if len(idx):
                np.bitwise_or.at(bits, idx // 32,
                                 np.uint32(1) << (idx % 32).astype(np.uint32))
            self._retained_bits = bits

        # shared extraction caches: decode each chunk's payloads once and
        # slice each (chunk, version) membership once, however many queries
        # in the session touch them
        payloads: Dict[int, Dict[int, bytes]] = {}
        members: Dict[Tuple[int, int], np.ndarray] = {}

        def _payloads(cid: int) -> Dict[int, bytes]:
            if cid not in payloads:
                payloads[cid] = fetched[cid][0].payloads()
            return payloads[cid]

        def _members(cid: int, vidx: int) -> np.ndarray:
            key = (cid, vidx)
            if key not in members:
                members[key] = fetched[cid][1].records_in_version(vidx)
            return members[key]

        return ExecContext(graph=self.graph, vidx=self._vidx,
                           indexes=self.indexes, fetched=fetched,
                           payloads=_payloads, members=_members,
                           retained_bits=self._retained_bits)

    # ------------------------------------------------------------- explain
    def explain(self, queries: Sequence[Query]) -> List[Dict[str, Any]]:
        """Render each query's chosen plan with predicted costs.

        Predictions come from :mod:`repro.core.costmodel` at the store's
        configured chunk size: a fetch-mode plan pays payload+map per
        candidate chunk, an index-only plan pays maps alone, a metadata
        plan pays nothing.  Compare ``predicted_chunks`` against the
        measured ``stats.chunks_fetched`` of an ``execute`` run to see how
        lossy the projections were for the workload.
        """
        self._check_fresh()
        out: List[Dict[str, Any]] = []
        for pq in self.plan_batch(queries):
            n = len(pq.cand)
            # maps are tiny next to payloads: model them at 1/16 chunk size
            map_b = max(self._chunk_bytes // 16, 1)
            if pq.mode == "fetch":
                n_keys, n_bytes = 2 * n, n * (self._chunk_bytes + map_b)
            elif pq.mode == "index_only":
                n_keys, n_bytes = n, n * map_b
            else:  # metadata — answered from the version graph
                n_keys = n_bytes = 0
            rts = 1 if n_keys else 0
            out.append({
                "plan": render_plan(pq),
                "mode": pq.mode,
                "predicted_chunks": n,
                "predicted_payload_chunks": n if pq.mode == "fetch" else 0,
                "predicted_round_trips": rts,
                "predicted_bytes": n_bytes,
                "predicted_seconds": costmodel.fetch_seconds(rts, n_bytes),
            })
        return out
