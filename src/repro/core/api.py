"""Plan/execute query engine: batched multi-query sessions over snapshots.

RStore's core insight (§2.3–§2.4) is that few large batched fetches beat many
small ones.  The seed API executed one query at a time, each paying two KVS
round trips (chunks, then maps).  This module turns retrieval into a
plan/execute pipeline over an immutable read view, in the spirit of the
query/update separation of versioned external-memory dictionaries
(Byde & Twigg):

1. **Plan** — every query's candidate chunk set is computed in one vectorized
   pass over the projection bitmaps: index-AND queries (point/multi-point/
   range) share a single pairwise ``and_popcount_batch`` kernel launch
   (``Projections.candidates_batch``); version/evolution queries read their
   posting lists directly.
2. **Dedupe** — candidate chunk ids are unioned across the batch; a chunk
   needed by ten queries is fetched once.
3. **Fetch** — ONE combined ``multiget`` for chunks *and* chunk maps
   (interleaved ``chunk/i``, ``map/i`` keys): a single backend round trip
   for the whole session.
4. **Extract** — per-query results are sliced out of the shared fetch; chunk
   payload decodes and per-version chunk-map slices are cached and reused
   across the queries that share them.

Usage::

    snap = rs.snapshot()                 # immutable read view (no flush)
    results = snap.execute([
        Q.version(v3),
        Q.record(v3, pk=7),
        Q.records(v3, [1, 2, 3]),
        Q.range(v3, 10, 19),
        Q.evolution(7),
        Q.where(v3, "color", 2),         # needs rs.create_index("color", ...)
        Q.where_range(v3, "size", 10, 20),
    ])
    results[0].value                     # {pk: payload, ...}
    results[0].stats                     # per-query QueryStats
    results.batch                        # batch-level QueryStats
                                         # (shared bytes attributed once)

Reads never mutate the store: ``Snapshot`` holds the flushed state and
``execute`` only touches the KVS.  ``RStore.get_*`` remain as thin wrappers
over single-query batches.

The write side mirrors this design: :class:`repro.core.ingest.WriteSession`
(``rs.writer()``) stages a wave of commits and group-flushes them through
one ``Backend.multiput`` — under :class:`repro.core.kvs.ShardedKVS` both
directions cost one round trip per shard touched, however many queries or
chunks the session carries.

Fault tolerance is below this layer: with replicated shards
(:class:`repro.core.replica.ReplicatedKVS`, via ``make_sharded_backend(...,
replication_factor=R)``) the session ``multiget`` survives a replica death
mid-workload unchanged — the group fails the batch over to a surviving
replica (at most one extra read round trip per failed-over shard batch)
and returns byte-identical results.  Only a whole shard group going down
surfaces here, as :class:`repro.core.replica.BackendUnavailable`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from .chunkstore import ChunkMap, StoredChunk
from .index import Projections
from .kvs import Backend
from .secondary import SecondaryIndex
from .types import unpack_ck
from .version_graph import VersionGraph


# ------------------------------------------------------------------- algebra
@dataclass(frozen=True)
class Query:
    """One retrieval request.  Build via the :class:`Q` factory."""

    kind: str          # version | record | records | range | evolution | where | where_range
    vid: Optional[int] = None
    pk: Optional[int] = None
    pks: Optional[Tuple[int, ...]] = None
    key_lo: Optional[int] = None         # pk bound (range) / value bound (where_range)
    key_hi: Optional[int] = None
    attr: Optional[str] = None           # secondary-index attribute (where*)
    value: Optional[int] = None          # exact attribute value (where)


class Q:
    """Query constructors: the session API's algebra (§2.4 query classes)."""

    @staticmethod
    def version(vid: int) -> Query:
        """Q1: every record live in version ``vid`` → Dict[pk, bytes]."""
        return Query(kind="version", vid=int(vid))

    @staticmethod
    def record(vid: int, pk: int) -> Query:
        """Point lookup of ``pk`` in ``vid`` → Optional[bytes]."""
        return Query(kind="record", vid=int(vid), pk=int(pk))

    @staticmethod
    def records(vid: int, pks: Iterable[int]) -> Query:
        """Multi-point lookup in ``vid`` → Dict[pk, bytes] (absent keys
        omitted)."""
        return Query(kind="records", vid=int(vid),
                     pks=tuple(int(p) for p in pks))

    @staticmethod
    def range(vid: int, key_lo: int, key_hi: int) -> Query:
        """Q2: records of ``vid`` with pk in [key_lo, key_hi] → Dict."""
        return Query(kind="range", vid=int(vid), key_lo=int(key_lo),
                     key_hi=int(key_hi))

    @staticmethod
    def evolution(pk: int) -> Query:
        """Q3: every distinct record ever stored under ``pk`` →
        List[(origin_vid, bytes)] in origin order."""
        return Query(kind="evolution", pk=int(pk))

    @staticmethod
    def where(vid: int, attr: str, value: int) -> Query:
        """Filtered scan: records of ``vid`` whose extracted ``attr`` equals
        ``value`` → Dict[pk, bytes].  Needs a secondary index on ``attr``
        (``rs.create_index``); results are exact — lossy chunk-granularity
        postings are post-filtered against the fetched payloads."""
        return Query(kind="where", vid=int(vid), attr=str(attr),
                     value=int(value))

    @staticmethod
    def where_range(vid: int, attr: str, lo: int, hi: int) -> Query:
        """Filtered scan: records of ``vid`` with extracted ``attr`` in
        ``[lo, hi]`` → Dict[pk, bytes].  Same index + exactness contract as
        :meth:`where`."""
        return Query(kind="where_range", vid=int(vid), attr=str(attr),
                     key_lo=int(lo), key_hi=int(hi))


# -------------------------------------------------------------------- results
@dataclass
class QueryStats:
    """Per-query (and, via :class:`BatchResult`, batch-level) fetch stats."""

    chunks_fetched: int = 0
    irrelevant_chunks: int = 0     # lossy-projection artifacts (§2.4)
    bytes_fetched: int = 0
    kvs_queries: int = 0           # backend round trips
    records_returned: int = 0
    cache_hits: int = 0            # batch-level: keys a CachingKVS served
    bytes_from_cache: int = 0      # batch-level: payload served at memory speed


@dataclass
class QueryResult:
    query: Query
    value: Any                     # Dict / Optional[bytes] / List — by kind
    stats: QueryStats


class BatchResult(List[QueryResult]):
    """``Snapshot.execute``'s return: a List[QueryResult] carrying the
    batch-level stats.  ``batch.bytes_fetched`` counts every fetched chunk
    once, no matter how many queries shared it; per-query stats attribute a
    chunk to every query that planned it."""

    batch: QueryStats

    def __init__(self, results: Iterable[QueryResult], batch: QueryStats):
        super().__init__(results)
        self.batch = batch


# ------------------------------------------------------------------- snapshot
class Snapshot:
    """Immutable read view over the flushed store state.

    Obtained via :meth:`RStore.snapshot`.  Holds the version graph,
    projections and KVS handle as of the last flush; ``execute`` plans and
    runs a whole batch of queries against it with one KVS round trip.
    Reads never mutate the store (the seed API's implicit flush-on-read is
    gone; ``RStoreConfig.auto_flush`` keeps it for back-compat at the
    ``RStore`` facade).

    Online (k=1) flushes after the snapshot only append chunks, so the
    snapshot keeps serving its versions; a full ``build()`` (including any
    k>1 flush) repartitions storage and *invalidates* the snapshot —
    ``execute`` then raises rather than silently reading rewritten chunks.
    """

    def __init__(self, graph: VersionGraph, proj: Projections,
                 kvs: Backend, epoch: Optional[int] = None,
                 current_epoch: Optional[Callable[[], int]] = None,
                 layout_epoch: Optional[int] = None,
                 current_layout_epoch: Optional[Callable[[], int]] = None,
                 indexes: Optional[Dict[str, SecondaryIndex]] = None,
                 repin: Optional[Callable[[], tuple]] = None,
                 staleness_lag: int = 0,
                 ) -> None:
        self.graph = graph
        self.proj = proj
        self.kvs = kvs
        # async ingest (core/flusher.py): committed-but-not-durable versions
        # at snapshot time.  0 for fresh (read-your-writes) snapshots; a
        # pinned snapshot reports how far behind the durable state it runs.
        # Staged versions are invisible to it — querying one fails loudly.
        self.staleness_lag = int(staleness_lag)
        # attr -> SecondaryIndex serving Q.where / Q.where_range plans
        self.indexes: Dict[str, SecondaryIndex] = indexes or {}
        self._vidx = {v: i for i, v in enumerate(graph.versions)}
        # rebuild-epoch guard: a full build() repartitions and rewrites the
        # chunk/* and map/* keys, so chunk ids planned from this snapshot's
        # projections would dereference to unrelated data.  Online (k=1)
        # flushes only append chunks and extend maps, so they don't
        # invalidate snapshots and don't bump the epoch.
        self._epoch = epoch
        self._current_epoch = current_epoch
        # layout-epoch guard: a compaction pass rewrites *some* chunks and
        # deletes their old keys, but preserves the logical content of every
        # retained version — so a stale snapshot is re-pinnable via
        # :meth:`refresh` instead of dead like after a build()
        self._layout_epoch = layout_epoch
        self._current_layout_epoch = current_layout_epoch
        self._repin = repin

    def _check_fresh(self) -> None:
        if (self._epoch is not None and self._current_epoch is not None
                and self._current_epoch() != self._epoch):
            raise RuntimeError(
                "snapshot invalidated by a full rebuild (build() or a k>1 "
                "flush repartitions chunk storage); take a new snapshot()")
        if (self._layout_epoch is not None
                and self._current_layout_epoch is not None
                and self._current_layout_epoch() != self._layout_epoch):
            raise RuntimeError(
                "a compaction pass re-partitioned chunk storage under this "
                "snapshot; call snapshot.refresh() to re-pin (compaction "
                "preserves the logical content of retained versions)")

    def refresh(self) -> "Snapshot":
        """Re-pin to the store's current physical layout after a compaction
        pass.  Compaction never changes what a retained version contains,
        so this is safe and cheap — unlike a full ``build()``, after which
        only a new ``snapshot()`` helps (and this raises)."""
        if (self._epoch is not None and self._current_epoch is not None
                and self._current_epoch() != self._epoch):
            raise RuntimeError(
                "snapshot invalidated by a full rebuild (build() or a k>1 "
                "flush repartitions chunk storage); take a new snapshot()")
        if self._repin is None:
            raise RuntimeError("snapshot is not attached to a store; "
                               "take a new snapshot()")
        pinned = self._repin()
        if len(pinned) == 3:
            self.proj, self.indexes, self._layout_epoch = pinned
        else:  # older 2-tuple repin hooks (no secondary indexes)
            self.proj, self._layout_epoch = pinned
        self._vidx = {v: i for i, v in enumerate(self.graph.versions)}
        return self

    # ---------------------------------------------------------------- plan
    def plan(self, queries: Sequence[Query]) -> List[np.ndarray]:
        """Candidate chunk ids per query — one vectorized pass.

        Version/evolution queries read their posting lists; all index-AND
        queries — primary (record/records/range) and secondary
        (where/where_range) alike — share a single pairwise bitmap-kernel
        launch via ``Projections.and_version_batch``: each query's posting
        lists OR into one bitmap row that is ANDed against its version's
        bitmap row.
        """
        empty = np.empty(0, np.int64)
        cands: List[Optional[np.ndarray]] = [None] * len(queries)
        anding: List[Tuple[int, List[Optional[np.ndarray]]]] = []
        anding_pos: List[int] = []
        for i, q in enumerate(queries):
            if q.vid is not None and self.graph.is_retired(q.vid):
                raise KeyError(
                    f"version {q.vid} was retired by a retention policy; "
                    "its content is no longer queryable")
            if q.kind == "version":
                cands[i] = self.proj.chunks_for_version(q.vid)
                continue
            if q.kind == "evolution":
                cands[i] = self.proj.chunks_for_key(q.pk)
                continue
            if q.kind in ("where", "where_range"):
                idx = self.indexes.get(q.attr)
                if idx is None:
                    raise KeyError(
                        f"no secondary index on attribute {q.attr!r}; "
                        "register one with rs.create_index(attr, extractor)")
                if q.kind == "where":
                    postings = [idx.postings_for(q.value)]
                else:
                    postings = idx.postings_in_range(q.key_lo, q.key_hi)
            elif q.kind in ("record", "records", "range"):
                if q.kind == "record":
                    pks = np.asarray([q.pk], dtype=np.int64)
                elif q.kind == "records":
                    pks = np.asarray(q.pks, dtype=np.int64)
                else:
                    pks = self.proj.keys_in_range(q.key_lo, q.key_hi)
                postings = [self.proj.key_chunks.get(int(p)) for p in pks]
            else:
                raise ValueError(f"unknown query kind {q.kind!r}")
            if not any(p is not None and len(p) for p in postings):
                cands[i] = empty
            else:
                anding.append((q.vid, postings))
                anding_pos.append(i)
        if anding:
            for pos, ids in zip(anding_pos,
                                self.proj.and_version_batch(anding)):
                cands[pos] = ids
        return cands  # type: ignore[return-value]

    # ------------------------------------------------------------ prefetch
    def _chunk_keys(self, chunk_ids: Iterable[int]) -> List[str]:
        return [k for c in chunk_ids for k in (f"chunk/{c}", f"map/{c}")]

    def prefetch(self, queries: Sequence[Query]) -> Dict[str, int]:
        """Warm the chunk cache with everything ``queries`` would fetch.

        A no-op (``{"warmed_keys": 0, ...}``) unless the snapshot's KVS is a
        :class:`~repro.core.cache.CachingKVS` layer.  The fill is a normal
        read-through ``multiget`` — already-cached keys cost nothing, misses
        arrive in ONE round trip (per shard) and pass the admission rule —
        so a subsequent ``execute`` of the same queries takes 0 backend read
        round trips.
        """
        self._check_fresh()
        if not getattr(self.kvs, "is_cache", False):
            return {"warmed_keys": 0, "round_trips": 0, "cache": 0}
        cands = self.plan(list(queries))
        nonempty = [c for c in cands if len(c)]
        all_ids = (np.unique(np.concatenate(nonempty)) if nonempty
                   else np.empty(0, np.int64))
        return self._warm(self._chunk_keys(int(c) for c in all_ids))

    def prefetch_evolution(self, pk: int, lineage_versions: int = 4
                           ) -> Dict[str, int]:
        """Warm the cache for ``Q.evolution(pk)`` by walking VersionGraph
        paths.

        The base warm set is ``pk``'s key posting list — exactly the chunks
        the evolution query plans, so it runs with 0 backend read round
        trips afterwards.  On top, the version-tree paths root→leaf are
        walked to recover the lineage of versions where ``pk`` actually
        changed (its record copies name their origin versions), and the
        newest ``lineage_versions`` of those get their version posting
        lists warmed too — an evolution read is typically followed by
        version/record reads at the versions where the record changed.
        """
        self._check_fresh()
        if not getattr(self.kvs, "is_cache", False):
            return {"warmed_keys": 0, "round_trips": 0, "cache": 0}
        cids = {int(c) for c in self.proj.chunks_for_key(pk)}

        # lineage walk: origins of pk's copies, ordered along tree paths
        store = self.graph.store
        rids = np.flatnonzero(store.keys() == pk)
        origin_set = {int(o) for o in store.origin_versions()[rids]}
        lineage: List[int] = []
        seen: set = set()
        for leaf in self.graph.leaves():
            if self.graph.is_retired(leaf):
                continue
            # path_to_root is leaf→root; reverse for chronological order
            for v in reversed(self.graph.path_to_root(leaf)):
                if v in origin_set and v not in seen:
                    seen.add(v)
                    lineage.append(v)
        for v in lineage[-lineage_versions:]:
            vc = self.proj.version_chunks.get(v)
            if vc is not None:
                cids.update(int(c) for c in vc)
        return self._warm(self._chunk_keys(sorted(cids)))

    def _warm(self, keys: List[str]) -> Dict[str, int]:
        s = self.kvs.stats
        q0, h0 = s.n_queries, s.n_cache_hits
        if keys:
            self.kvs.multiget(keys)
        return {"warmed_keys": len(keys),
                "round_trips": s.n_queries - q0,
                "already_cached": s.n_cache_hits - h0,
                "cache": 1}

    # ------------------------------------------------------------- execute
    def execute(self, queries: Sequence[Query]) -> BatchResult:
        """Plan → dedupe → ONE interleaved multiget → extract."""
        self._check_fresh()
        queries = list(queries)
        cands = self.plan(queries)

        nonempty = [c for c in cands if len(c)]
        all_ids = (np.unique(np.concatenate(nonempty)) if nonempty
                   else np.empty(0, np.int64))

        batch = QueryStats()
        batch.chunks_fetched = len(all_ids)
        fetched: Dict[int, Tuple[StoredChunk, ChunkMap, int]] = {}
        if len(all_ids):
            q0 = self.kvs.stats.n_queries
            b0 = self.kvs.stats.bytes_fetched
            h0 = self.kvs.stats.n_cache_hits
            c0 = self.kvs.stats.bytes_served_from_cache
            # interleaved chunk/map keys: chunks + maps in ONE round trip.
            # Under a CachingKVS the hit/miss partition happens inside this
            # multiget — cached keys are served from memory and ONE inner
            # fetch covers the misses, so kvs_queries is 0 on a warm cache.
            keys = [k for c in all_ids for k in (f"chunk/{c}", f"map/{c}")]
            blobs = self.kvs.multiget(keys)
            batch.kvs_queries = self.kvs.stats.n_queries - q0
            batch.bytes_fetched = self.kvs.stats.bytes_fetched - b0
            batch.cache_hits = self.kvs.stats.n_cache_hits - h0
            batch.bytes_from_cache = self.kvs.stats.bytes_served_from_cache - c0
            for j, cid in enumerate(all_ids):
                cb, mb = blobs[2 * j], blobs[2 * j + 1]
                fetched[int(cid)] = (StoredChunk.from_bytes(cb),
                                     ChunkMap.from_bytes(mb),
                                     len(cb) + len(mb))

        # retention-aware evolution: with retired versions around, a kept
        # chunk may still hold record copies reachable from no retained
        # version; their chunk-map bitmap rows tell us (no retained bit set)
        # and they are filtered out of Q3 results
        self._retained_bits = None
        if self.graph.has_retired():
            order = self.graph.versions
            idx = np.asarray([i for i, v in enumerate(order)
                              if not self.graph.is_retired(v)], dtype=np.int64)
            bits = np.zeros((len(order) + 31) // 32, dtype=np.uint32)
            if len(idx):
                np.bitwise_or.at(bits, idx // 32,
                                 np.uint32(1) << (idx % 32).astype(np.uint32))
            self._retained_bits = bits

        # shared extraction caches: decode each chunk's payloads once and
        # slice each (chunk, version) membership once, however many queries
        # in the session touch them
        payloads: Dict[int, Dict[int, bytes]] = {}
        members: Dict[Tuple[int, int], np.ndarray] = {}

        def _payloads(cid: int) -> Dict[int, bytes]:
            if cid not in payloads:
                payloads[cid] = fetched[cid][0].payloads()
            return payloads[cid]

        def _members(cid: int, vidx: int) -> np.ndarray:
            key = (cid, vidx)
            if key not in members:
                members[key] = fetched[cid][1].records_in_version(vidx)
            return members[key]

        results: List[QueryResult] = []
        for q, cand in zip(queries, cands):
            stats = QueryStats(
                chunks_fetched=len(cand),
                bytes_fetched=sum(fetched[int(c)][2] for c in cand),
                kvs_queries=batch.kvs_queries if len(cand) else 0,
            )
            value = self._extract(q, cand, fetched, _payloads, _members, stats)
            batch.records_returned += stats.records_returned
            batch.irrelevant_chunks += stats.irrelevant_chunks
            results.append(QueryResult(query=q, value=value, stats=stats))
        return BatchResult(results, batch)

    # ------------------------------------------------------------- extract
    def _extract(self, q: Query, cand: np.ndarray, fetched, _payloads,
                 _members, stats: QueryStats):
        if q.kind == "version":
            out: Dict[int, bytes] = {}
            vidx = self._vidx[q.vid]
            for c in cand:
                cid = int(c)
                cmap = fetched[cid][1]
                locs = _members(cid, vidx)
                if len(locs) == 0:
                    stats.irrelevant_chunks += 1
                    continue
                pay = _payloads(cid)
                for li in locs:
                    pk, _ = unpack_ck(int(cmap.cks[li]))
                    out[pk] = pay[int(li)]
            stats.records_returned = len(out)
            return out

        if q.kind in ("record", "records", "range"):
            vidx = self._vidx[q.vid]
            out = {}
            for c in cand:
                cid = int(c)
                cmap = fetched[cid][1]
                locs = _members(cid, vidx)
                keys = cmap.cks[locs] >> 32
                if q.kind == "record":
                    sel = locs[keys == q.pk]
                elif q.kind == "records":
                    sel = locs[np.isin(keys, np.asarray(q.pks, dtype=np.int64))]
                else:
                    sel = locs[(keys >= q.key_lo) & (keys <= q.key_hi)]
                if len(sel) == 0:
                    stats.irrelevant_chunks += 1
                    continue
                pay = _payloads(cid)
                for li in sel:
                    pk, _ = unpack_ck(int(cmap.cks[li]))
                    out[pk] = pay[int(li)]
            stats.records_returned = len(out)
            if q.kind == "record":
                return out.get(q.pk)
            return out

        if q.kind in ("where", "where_range"):
            # exact post-filter: the lossy postings only say a chunk *may*
            # hold a match (the record copies could be dead, live in other
            # versions only, or share a chunk with the real match) — so the
            # attribute is re-extracted from every record live in vid and
            # the predicate applied exactly.  Lossiness never leaks.
            idx = self.indexes[q.attr]
            vidx = self._vidx[q.vid]
            out = {}
            for c in cand:
                cid = int(c)
                cmap = fetched[cid][1]
                locs = _members(cid, vidx)
                if len(locs) == 0:
                    stats.irrelevant_chunks += 1
                    continue
                pay = _payloads(cid)
                hit = False
                for li in locs:
                    p = pay[int(li)]
                    v = idx.extractor(p).get(q.attr)
                    if v is None:
                        continue
                    if (v == q.value if q.kind == "where"
                            else q.key_lo <= v <= q.key_hi):
                        pk, _ = unpack_ck(int(cmap.cks[li]))
                        out[pk] = p
                        hit = True
                if not hit:
                    stats.irrelevant_chunks += 1
            stats.records_returned = len(out)
            return out

        if q.kind == "evolution":
            evo: List[Tuple[int, bytes]] = []
            retained_bits = getattr(self, "_retained_bits", None)
            for c in cand:
                cid = int(c)
                cmap = fetched[cid][1]
                sel = np.flatnonzero((cmap.cks >> 32) == q.pk)
                if retained_bits is not None and len(sel):
                    w = min(cmap.bitmap.shape[1], len(retained_bits))
                    alive = (cmap.bitmap[sel, :w]
                             & retained_bits[:w]).any(axis=1)
                    sel = sel[alive]
                if len(sel) == 0:
                    stats.irrelevant_chunks += 1
                    continue
                pay = _payloads(cid)
                for li in sel:
                    _, origin = unpack_ck(int(cmap.cks[li]))
                    evo.append((origin, pay[int(li)]))
            evo.sort(key=lambda t: self._vidx.get(t[0], 1 << 30))
            stats.records_returned = len(evo)
            return evo

        raise ValueError(f"unknown query kind {q.kind!r}")
