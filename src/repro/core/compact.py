"""Background compaction & retention GC — the maintenance path.

RStore's online algorithm (§4) only ever appends: each commit batch becomes
fresh chunks and old ones are never revisited (the paper defers
re-partitioning to future work).  Under a long-running workload the layout
therefore degrades into many small, low-span chunks, and storage for
versions nobody needs anymore is never reclaimed.  Byde & Twigg's versioned
external-memory dictionaries show the missing lever: *amortized background
rewriting* trades a bounded amount of write cost back into query cost.
This module is that lever, split into two layers:

**Retention** (:func:`keep_all` / :func:`keep_last` / :func:`keep_tagged`,
applied via ``RStore.retain(policy)``) prunes versions from the
:class:`~repro.core.version_graph.VersionGraph`.  Retired versions keep
their tree structure (stable version indices for stored chunk-map bitmaps)
but lose their membership; records reachable from no retained version
become *garbage*.

**Compaction** (:class:`Compactor`, applied via ``RStore.compact()``)
(a) *measures* layout health from the in-memory index alone — per-chunk
liveness, a chunk-size histogram, and a fragmentation score that prices the
current layout against an ideally-packed one with the Table-1
:mod:`~repro.core.costmodel` query-cost formulas; (b) *selects* candidate
chunk groups (small online-batch chunks plus chunks below a liveness
threshold) and rewrites their live records through the store's configured
partition algorithm (the same §4 restricted adaptation the online flush
uses), staging every new chunk and rebuilt chunk map into ONE group commit
— one ``multiput`` round trip per backend shard touched, exactly like a
:class:`~repro.core.ingest.WriteSession` flush; and (c) *deletes* the
superseded chunk/map keys through the :class:`~repro.core.kvs.Backend`
protocol's ``multidelete`` — one delete round trip per shard touched, with
:class:`~repro.core.kvs.ShardedDeviceKVS` returning the freed extents to
its slot free list.  Under replicated shards
(:class:`~repro.core.replica.ReplicatedKVS`) both the rewrite multiput and
the GC multidelete fan out across every live replica of each group; a
replica that is down records the missed deletes in its repair log, so
recovery never resurrects reclaimed chunks.

Snapshot coherence is epoch-based: a pass bumps the store's *layout epoch*.
Open :class:`~repro.core.api.Snapshot`\\ s notice on their next ``execute``
and raise, but — because compaction preserves the logical content of every
retained version — they re-pin with ``snapshot.refresh()`` instead of being
hard-invalidated the way a full ``build()`` invalidates them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import costmodel
from .index import Projections
from .online import partition_batch

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .ingest import RStore
    from .version_graph import VersionGraph

# same constants as KVSStats.simulated_seconds — the §2.3 Cassandra-like
# model, now owned by costmodel so the chunk cache prices with them too
PER_QUERY_S = costmodel.PER_QUERY_S
BANDWIDTH_BPS = costmodel.BANDWIDTH_BPS


# ---------------------------------------------------------- retention policies
@dataclass(frozen=True)
class RetentionPolicy:
    """Which versions to keep.  Build via :func:`keep_all` /
    :func:`keep_last` / :func:`keep_tagged`."""

    kind: str                        # all | last | tagged
    k: int = 0
    vids: Tuple[int, ...] = ()

    def resolve(self, graph: "VersionGraph") -> List[int]:
        """The retained version ids under this policy (insertion order)."""
        current = graph.retained_versions()
        if self.kind == "all":
            return current
        if self.kind == "last":
            if self.k < 1:
                raise ValueError("keep_last needs k >= 1")
            return current[-self.k:]
        if self.kind == "tagged":
            keep = set(self.vids)
            if not keep:
                raise ValueError("keep_tagged needs at least one version")
            missing = keep - set(current)
            if missing:
                raise ValueError(
                    f"keep_tagged: unknown or already-retired version(s) "
                    f"{sorted(missing)}")
            return [v for v in current if v in keep]
        raise ValueError(f"unknown retention policy {self.kind!r}")


def keep_all() -> RetentionPolicy:
    """Retain everything (the no-op policy)."""
    return RetentionPolicy(kind="all")


def keep_last(k: int) -> RetentionPolicy:
    """Retain only the most recent ``k`` versions in commit order — the
    training-loop policy (cap checkpoint storage at a window)."""
    return RetentionPolicy(kind="last", k=int(k))


def keep_tagged(vids: Iterable[int]) -> RetentionPolicy:
    """Retain exactly the listed versions (pinned releases, milestones)."""
    return RetentionPolicy(kind="tagged", vids=tuple(int(v) for v in vids))


# -------------------------------------------------------------- layout health
@dataclass
class LayoutHealth:
    """What the maintenance path knows about the physical layout — computed
    entirely from the in-memory index (no KVS traffic)."""

    n_chunks: int
    stored_bytes: int                   # encoded chunk blob bytes in the KVS
    n_records_stored: int
    n_live_records: int
    n_dead_records: int                 # stored but reachable from no retained version
    live_payload_bytes: int
    dead_payload_bytes: int
    dead_frac: float                    # dead / stored payload bytes
    chunk_liveness: Dict[int, float]    # cid -> live fraction of its records
    chunk_bytes: Dict[int, int]         # cid -> stored blob size
    size_histogram: Tuple[np.ndarray, np.ndarray]  # (counts, edges/capacity)
    span_factor: float                  # Σ span(v) / Σ ideal_chunks(v)
    frag_score: float                   # cost-model $ of layout vs ideal (≥~1)
    est_read_seconds: float             # mean simulated Q1 seconds, current layout
    est_read_seconds_ideal: float       # same under a perfectly packed layout
    model: Dict[str, float] = field(default_factory=dict)  # calibrated Table-1


def measure_layout(rs: "RStore", per_query_s: float = PER_QUERY_S,
                   bandwidth_Bps: float = BANDWIDTH_BPS) -> LayoutHealth:
    """Measure layout health for ``rs``'s flushed state.

    The fragmentation score prices full-version retrieval with the Table-1
    cost formulas (per-request overhead + transfer, the §2.3 model): the
    current layout pays ``span(v)`` requests and fetches every byte of every
    touched chunk (dead records included), the ideal layout pays
    ``ceil(live_bytes(v)/C)`` requests for exactly the live bytes.  Their
    ratio is the score — 1.0 is the information-theoretic floor, and growth
    over time is precisely the §4 online-appending degradation.
    """
    graph = rs.graph
    cap = rs.config.capacity
    live_mask = graph.live_record_mask()
    sizes = graph.store.sizes

    chunk_liveness: Dict[int, float] = {}
    n_stored = n_live = 0
    live_pay = dead_pay = 0
    for cid, rids in rs._chunk_records.items():
        lm = live_mask[rids]
        chunk_liveness[cid] = float(lm.mean()) if len(rids) else 0.0
        n_stored += len(rids)
        n_live += int(lm.sum())
        live_pay += int(sizes[rids[lm]].sum())
        dead_pay += int(sizes[rids[~lm]].sum())

    chunk_bytes = dict(rs._chunk_bytes)
    stored = int(sum(chunk_bytes.values()))
    edges = np.array([0, 0.25, 0.5, 0.75, 1.0, 1.25, np.inf])
    counts, _ = np.histogram(
        np.asarray(list(chunk_bytes.values()), dtype=np.float64) / max(cap, 1),
        bins=edges)

    # cost-model pricing of Q1 over every retained version
    retained = [v for v in graph.retained_versions()
                if rs.proj is not None and v in rs.proj.version_chunks]
    span_sum = ideal_sum = 0
    act_s = ideal_s = 0.0
    member_counts: List[int] = []
    for v in retained:
        vchunks = rs.proj.version_chunks[v]
        m = graph.members(v)
        member_counts.append(len(m))
        vbytes = int(sizes[m].sum())
        span = len(vchunks)
        ideal = max(1, math.ceil(vbytes / max(cap, 1)))
        span_sum += span
        ideal_sum += ideal
        fetched = int(sum(chunk_bytes.get(int(c), 0) for c in vchunks))
        act_s += span * per_query_s + fetched / bandwidth_Bps
        ideal_s += ideal * per_query_s + vbytes / bandwidth_Bps
    nv = max(1, len(retained))
    span_factor = span_sum / max(1, ideal_sum)
    frag = act_s / ideal_s if ideal_s > 0 else 1.0

    # calibrated Table-1 estimate: back out the workload parameters from the
    # measured aggregates and price the layout with costmodel.rstore
    model: Dict[str, float] = {}
    if retained and member_counts:
        m_v = float(np.mean(member_counts))
        s = live_pay / max(1, n_live) if n_live else 1.0
        if len(retained) > 1 and m_v > 0 and s > 0:
            d = (live_pay / (m_v * s) - 1.0) / (len(retained) - 1)
        else:
            d = 0.0
        w = costmodel.Workload(n=len(retained), m_v=m_v,
                               d=float(np.clip(d, 0.0, 1.0)), c=1.0, s=s,
                               s_c=float(max(cap, 1)))
        model = costmodel.rstore(w, span_factor=span_factor)

    return LayoutHealth(
        n_chunks=len(rs._chunk_records), stored_bytes=stored,
        n_records_stored=n_stored, n_live_records=n_live,
        n_dead_records=n_stored - n_live, live_payload_bytes=live_pay,
        dead_payload_bytes=dead_pay,
        dead_frac=dead_pay / max(1, live_pay + dead_pay),
        chunk_liveness=chunk_liveness, chunk_bytes=chunk_bytes,
        size_histogram=(counts, edges), span_factor=span_factor,
        frag_score=frag, est_read_seconds=act_s / nv,
        est_read_seconds_ideal=ideal_s / nv, model=model)


# ------------------------------------------------------------------- reports
@dataclass
class CompactionReport:
    mode: str                       # "pass" | "noop" | "rebuild"
    candidates: int = 0
    chunks_written: int = 0
    chunks_deleted: int = 0
    records_rewritten: int = 0
    records_dropped: int = 0        # dead copies physically reclaimed
    bytes_written: int = 0
    bytes_deleted: int = 0
    stored_bytes_before: int = 0
    stored_bytes_after: int = 0
    write_round_trips: int = 0
    delete_round_trips: int = 0
    frag_before: float = 1.0
    frag_after: float = 1.0
    layout_epoch: int = 0

    @property
    def reclaimed_frac(self) -> float:
        if self.stored_bytes_before <= 0:
            return 0.0
        return 1.0 - self.stored_bytes_after / self.stored_bytes_before


# ----------------------------------------------------------------- compactor
class Compactor:
    """One background maintenance pass over an :class:`RStore`.

    ``liveness_threshold`` — chunks whose live-record fraction is below this
    are rewritten (1.0 would rewrite on a single dead record; the default
    0.75 lets mostly-live chunks amortize until enough of them has died).
    ``small_chunk_frac`` — chunks smaller than this fraction of the
    configured capacity are the §4 online-batch fragments; two or more of
    them get merged (a lone small chunk has no merge partner and is left
    alone).  ``min_dead_frac`` / ``frag_trigger`` drive :meth:`should_run`,
    the cost-model trigger a background loop polls.
    """

    def __init__(self, rs: "RStore", liveness_threshold: float = 0.75,
                 small_chunk_frac: float = 0.5, min_dead_frac: float = 0.10,
                 frag_trigger: float = 1.5) -> None:
        self.rs = rs
        self.liveness_threshold = float(liveness_threshold)
        self.small_chunk_frac = float(small_chunk_frac)
        self.min_dead_frac = float(min_dead_frac)
        self.frag_trigger = float(frag_trigger)

    # ------------------------------------------------------------- measure
    def health(self) -> LayoutHealth:
        return measure_layout(self.rs)

    def should_run(self, health: Optional[LayoutHealth] = None) -> bool:
        """Cost-model trigger: compact once enough stored bytes are dead or
        the fragmentation score says queries overpay by ``frag_trigger``×."""
        h = health or self.health()
        return (h.dead_frac >= self.min_dead_frac
                or h.frag_score >= self.frag_trigger)

    # -------------------------------------------------------------- select
    def select(self, health: LayoutHealth) -> np.ndarray:
        """Candidate chunk ids: below the liveness threshold, plus small
        online-batch fragments (only if they have a merge partner)."""
        low_live = {cid for cid, lv in health.chunk_liveness.items()
                    if lv < self.liveness_threshold}
        cut = self.small_chunk_frac * self.rs.config.capacity
        small = [cid for cid, b in health.chunk_bytes.items()
                 if b < cut and cid not in low_live]
        if len(small) < 2:
            small = []
        return np.asarray(sorted(low_live | set(small)), dtype=np.int64)

    # ---------------------------------------------------------------- pass
    def run_pass(self) -> CompactionReport:
        """Measure → select → rewrite (ONE multiput) → GC (ONE multidelete).

        Round-trip contract (the ci.sh gate): a pass costs exactly one write
        round trip per backend shard its new chunks touch plus one delete
        round trip per shard its superseded keys touch — however many chunks
        move.  A pass with nothing to do costs zero round trips.
        """
        rs = self.rs
        rs._check_no_open_writer("compact()")
        if rs._flusher is not None:
            # drain barrier (async ingest): staged versions — and any
            # replay held from a failed drain — land in the OLD layout
            # before the pass rewrites it, so a later replay can never
            # resurrect keys this pass deletes
            rs._flusher.drain()
        elif rs.pending:
            if rs.config.auto_flush:
                rs.flush()
            else:
                raise RuntimeError(
                    f"{len(rs.pending)} unflushed version(s); compaction "
                    "works on the flushed layout — call flush() first")
        if rs.proj is None or not rs._chunk_records:
            return CompactionReport(mode="noop", layout_epoch=rs._layout_epoch)
        if rs.config.k > 1:
            return self._rebuild_pass()

        before = self.health()
        cands = self.select(before)
        if not len(cands):
            return CompactionReport(
                mode="noop", stored_bytes_before=before.stored_bytes,
                stored_bytes_after=before.stored_bytes,
                frag_before=before.frag_score, frag_after=before.frag_score,
                layout_epoch=rs._layout_epoch)

        graph = rs.graph
        live_mask = graph.live_record_mask()
        cand_rids = np.concatenate([rs._chunk_records[int(c)] for c in cands])
        rewrite = cand_rids[live_mask[cand_rids]]
        dead = cand_rids[~live_mask[cand_rids]]

        # rewrite through the configured algorithm, restricted to the live
        # records of the candidates (the same §4 adaptation the online flush
        # uses; batch = the whole tree so every record finds its origin)
        placed = np.ones(len(graph.store), dtype=bool)
        placed[rewrite] = False
        part = partition_batch(graph, graph.versions, placed,
                               rs.config.algorithm, rs.config.capacity,
                               chunk_id_base=rs.n_chunks, records=rewrite,
                               **rs.config.algo_kwargs())
        mask = part.record_to_chunk >= 0
        rs.r2c[:len(mask)][mask] = part.record_to_chunk[mask]
        rs.r2c[dead] = -1
        rs.n_chunks += part.num_chunks

        # stage every new chunk + chunk map, commit in ONE multiput (the
        # WriteSession group-commit machinery), then GC the superseded keys
        # in ONE multidelete — new data lands before old data goes away
        csr = graph.record_version_index_csr()
        nv = graph.num_versions
        vidx_of = {v: i for i, v in enumerate(graph.versions)}
        writes = rs._stage_chunk_writes(part.chunks, vidx_of, nv, csr)
        bytes_written = sum(rs._chunk_bytes[c.chunk_id] for c in part.chunks)

        del_keys = [k for c in cands
                    for k in (f"chunk/{int(c)}", f"map/{int(c)}")]
        # secondary indexes: retire the candidates' postings, extend for the
        # rewritten chunks — dirty idx2/ buckets ride the same multiput, and
        # buckets emptied by the pass join the same multidelete (no orphans)
        if rs._indexes:
            new_chunks = [(c.chunk_id, c.record_ids) for c in part.chunks]
            for idx in rs._indexes.values():
                idx.remove_chunks(int(c) for c in cands)
                idx.add_chunks(new_chunks, graph.store.payload)
                iw, idel = idx.stage_writes()
                writes.extend(iw)
                del_keys.extend(idel)

        s0 = rs.kvs.stats.snapshot()
        rs.kvs.multiput(writes)
        rs.kvs.multidelete(del_keys)
        write_rts = rs.kvs.stats.n_put_queries - s0.n_put_queries
        delete_rts = rs.kvs.stats.n_delete_queries - s0.n_delete_queries

        bytes_deleted = 0
        for c in cands:
            bytes_deleted += rs._chunk_bytes.pop(int(c))
            del rs._chunk_records[int(c)]

        # new layout epoch: open snapshots re-pin via snapshot.refresh(),
        # and the chunk cache flushes the superseded keys at the same moment
        rs.proj = Projections.build_from_r2c(graph, rs.r2c, rs.n_chunks)
        rs._layout_epoch += 1
        rs._notify_layout_change(del_keys)
        after = self.health()
        return CompactionReport(
            mode="pass", candidates=len(cands),
            chunks_written=part.num_chunks, chunks_deleted=len(cands),
            records_rewritten=len(rewrite), records_dropped=len(dead),
            bytes_written=bytes_written, bytes_deleted=bytes_deleted,
            stored_bytes_before=before.stored_bytes,
            stored_bytes_after=after.stored_bytes,
            write_round_trips=write_rts, delete_round_trips=delete_rts,
            frag_before=before.frag_score, frag_after=after.frag_score,
            layout_epoch=rs._layout_epoch)

    def _rebuild_pass(self) -> CompactionReport:
        """k>1 (sub-chunk compression) fallback: the online algorithm cannot
        re-group sub-chunks, so — exactly like flush() — the pass is a full
        retention-aware build().  build() now GCs stale chunk keys itself;
        this still hard-invalidates snapshots (documented: rebuilds always
        have)."""
        rs = self.rs
        before = self.health()
        s0 = rs.kvs.stats.snapshot()
        rs.build()
        after = self.health()
        return CompactionReport(
            mode="rebuild", candidates=before.n_chunks,
            chunks_written=after.n_chunks, chunks_deleted=before.n_chunks,
            records_rewritten=after.n_records_stored,
            records_dropped=before.n_records_stored - after.n_records_stored,
            bytes_written=after.stored_bytes, bytes_deleted=before.stored_bytes,
            stored_bytes_before=before.stored_bytes,
            stored_bytes_after=after.stored_bytes,
            write_round_trips=rs.kvs.stats.n_put_queries - s0.n_put_queries,
            delete_round_trips=(rs.kvs.stats.n_delete_queries
                                - s0.n_delete_queries),
            frag_before=before.frag_score, frag_after=after.frag_score,
            layout_epoch=rs._layout_epoch)
