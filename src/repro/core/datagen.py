"""Synthetic versioned-dataset generator (§5.1).

Reproduces the paper's experimental data construction: a version graph is
grown from a single root ("method outlined in [4]" — versions either extend
the current head, branch off an existing version, or merge), and every
non-root version updates/deletes/inserts a configurable fraction of its
parent's records, with record selection either uniform ("Random") or Zipf
("Skewed").  For compression studies, a modified record differs from its
parent payload by at most ``p_d`` (the paper's P_d knob).

Everything is deterministic given ``seed``.  Payload generation is optional —
span/partitioning experiments only need record sizes, matching the paper's
use of chunk-count as the storage/retrieval proxy.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import pack_ck_array
from .version_graph import RecordStore, VersionGraph


@dataclass(frozen=True)
class DatasetSpec:
    """Knobs mirror Table 2's dataset dimensions."""

    n_versions: int = 100
    n_base_records: int = 1000
    pct_update: float = 0.05          # fraction of parent records changed/version
    update_dist: str = "random"       # "random" | "zipf"  (paper: Random/Skewed)
    zipf_a: float = 1.2
    frac_modify: float = 0.90         # of the selected records: modify
    frac_insert: float = 0.05         # new primary keys (relative count)
    frac_delete: float = 0.05
    record_size: int = 256            # mean payload bytes
    size_sigma: float = 0.0           # lognormal sigma (0 = fixed size)
    p_d: Optional[float] = None       # bounded per-record change (compression)
    branch_prob: float = 0.0          # 0 → linear chain (dataset A/B family)
    merge_prob: float = 0.0          # DAG merges (exercises Fig. 4 conversion)
    payloads: bool = False
    # structured prefix for secondary-index experiments: the first
    # 4*attr_fields bytes of every payload are little-endian uint32
    # attribute values drawn uniformly from [0, attr_cardinality) — the
    # layout core/secondary.py's datagen_extractor(attr_fields) reads
    attr_fields: int = 0
    attr_cardinality: int = 256
    seed: int = 0

    def label(self) -> str:
        return (f"v{self.n_versions}_r{self.n_base_records}_u{self.pct_update}"
                f"_{self.update_dist}_b{self.branch_prob}_s{self.seed}")


# Scaled-down analogues of the paper's Table 2 datasets (same structure,
# container-sized).  Names match the paper's.
PAPER_DATASETS: Dict[str, DatasetSpec] = {
    # A-family: deep linear chains
    "A0": DatasetSpec(n_versions=300, n_base_records=4000, pct_update=0.50,
                      update_dist="random", branch_prob=0.0, seed=10),
    "A1": DatasetSpec(n_versions=300, n_base_records=4000, pct_update=0.05,
                      update_dist="zipf", branch_prob=0.0, seed=11),
    "A2": DatasetSpec(n_versions=300, n_base_records=4000, pct_update=0.05,
                      update_dist="random", branch_prob=0.0, seed=12),
    # B-family: mostly-deep trees
    "B0": DatasetSpec(n_versions=1001, n_base_records=2000, pct_update=0.05,
                      update_dist="zipf", branch_prob=0.02, seed=20),
    "B1": DatasetSpec(n_versions=1001, n_base_records=2000, pct_update=0.05,
                      update_dist="random", branch_prob=0.02, seed=21),
    "B2": DatasetSpec(n_versions=1001, n_base_records=2000, pct_update=0.10,
                      update_dist="random", branch_prob=0.02, seed=22),
    # C-family: many versions, shallower trees
    "C0": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.10,
                      update_dist="random", branch_prob=0.10, seed=30),
    "C1": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.01,
                      update_dist="random", branch_prob=0.10, seed=31),
    "C2": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.05,
                      update_dist="zipf", branch_prob=0.10, seed=32),
    # D-family: shallow bushy trees
    "D0": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.10,
                      update_dist="random", branch_prob=0.25, seed=40),
    "D1": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.01,
                      update_dist="random", branch_prob=0.25, seed=41),
    "D2": DatasetSpec(n_versions=2000, n_base_records=1000, pct_update=0.05,
                      update_dist="zipf", branch_prob=0.25, seed=42),
}


def _sizes(rng: np.random.Generator, n: int, spec: DatasetSpec) -> np.ndarray:
    if spec.size_sigma <= 0:
        return np.full(n, spec.record_size, dtype=np.int64)
    s = rng.lognormal(mean=np.log(spec.record_size), sigma=spec.size_sigma, size=n)
    return np.maximum(8, s).astype(np.int64)


def _payload(rng: np.random.Generator, size: int,
             spec: Optional[DatasetSpec] = None) -> bytes:
    raw = rng.integers(0, 256, size=size, dtype=np.uint8)
    if spec is not None and spec.attr_fields > 0:
        vals = rng.integers(0, spec.attr_cardinality,
                            size=spec.attr_fields, dtype=np.uint32)
        pre = np.frombuffer(vals.astype("<u4").tobytes(), dtype=np.uint8)
        if len(raw) < len(pre):       # payload grows to fit the attr prefix
            raw = np.concatenate([raw, np.zeros(len(pre) - len(raw),
                                                np.uint8)])
        raw[:len(pre)] = pre
    return raw.tobytes()


def _mutate(rng: np.random.Generator, parent: bytes, p_d: Optional[float],
            spec: Optional[DatasetSpec] = None) -> bytes:
    """Child payload: contiguous block rewrite bounded by P_d (or full rewrite)."""
    if p_d is None:
        return _payload(rng, len(parent), spec)
    n = len(parent)
    span = max(1, int(n * p_d))
    off = int(rng.integers(0, max(1, n - span + 1)))
    buf = bytearray(parent)
    buf[off:off + span] = _payload(rng, span)
    return bytes(buf)


def generate(spec: DatasetSpec) -> VersionGraph:
    rng = np.random.default_rng(spec.seed)
    store = RecordStore()
    graph = VersionGraph(store)

    # ---- root version --------------------------------------------------
    n0 = spec.n_base_records
    keys0 = np.arange(n0, dtype=np.int64)
    cks0 = pack_ck_array(keys0, np.zeros(n0, dtype=np.int64))
    sizes0 = _sizes(rng, n0, spec)
    payloads0 = ([_payload(rng, int(s), spec) for s in sizes0]
                 if spec.payloads else None)
    rids0 = store.add_batch(cks0, sizes0, payloads0)
    graph.add_root(0, rids0)

    next_key = n0
    head = 0                           # current chain head
    # latest record id per (version, primary key) is derivable from membership;
    # we keep a per-version dict for parent lookup during generation.
    key_to_rid: Dict[int, Dict[int, int]] = {0: dict(zip(keys0.tolist(), rids0.tolist()))}

    for vid in range(1, spec.n_versions):
        # ---- choose parent(s): extend head, branch, or merge ----------
        r = rng.random()
        if r < spec.branch_prob and vid > 2:
            parent = int(rng.integers(0, vid))
        else:
            parent = head
        parents = [parent]
        if spec.merge_prob > 0 and vid > 3 and rng.random() < spec.merge_prob:
            other = int(rng.integers(0, vid))
            if other != parent:
                parents.append(other)

        pmap = key_to_rid[parent]
        pkeys = np.fromiter(pmap.keys(), dtype=np.int64, count=len(pmap))

        # ---- merge: pull in keys exclusive to the second parent (Fig. 4)
        merged_extra: Dict[int, int] = {}
        if len(parents) > 1:
            omap = key_to_rid[parents[1]]
            for k, rid in omap.items():
                if k not in pmap:
                    merged_extra[k] = rid

        # ---- pick records to change -----------------------------------
        n_sel = max(1, int(len(pkeys) * spec.pct_update))
        if spec.update_dist == "zipf":
            w = 1.0 / np.power(pkeys + 1.0, spec.zipf_a)
            w /= w.sum()
            sel = rng.choice(pkeys, size=min(n_sel, len(pkeys)), replace=False, p=w)
        else:
            sel = rng.choice(pkeys, size=min(n_sel, len(pkeys)), replace=False)

        tot = spec.frac_modify + spec.frac_insert + spec.frac_delete
        n_mod = int(len(sel) * spec.frac_modify / tot)
        n_del = int(len(sel) * spec.frac_delete / tot)
        n_ins = max(0, len(sel) - n_mod - n_del)
        mod_keys = sel[:n_mod]
        del_keys = sel[n_mod:n_mod + n_del]

        # ---- build delta ------------------------------------------------
        new_keys = np.arange(next_key, next_key + n_ins, dtype=np.int64)
        next_key += n_ins
        add_keys = np.concatenate([mod_keys, new_keys])
        add_cks = pack_ck_array(add_keys, np.full(len(add_keys), vid, dtype=np.int64))
        add_sizes = np.concatenate([
            # modified records keep their parent's size (bounded change)
            np.array([store.size_of(pmap[int(k)]) for k in mod_keys],
                     dtype=np.int64)
            if n_mod else np.empty(0, np.int64),
            _sizes(rng, n_ins, spec),
        ])
        add_payloads = None
        if spec.payloads:
            add_payloads = [
                _mutate(rng, store.payload(pmap[int(k)]), spec.p_d, spec)
                for k in mod_keys
            ] + [_payload(rng, int(s), spec) for s in add_sizes[n_mod:]]
        add_rids = store.add_batch(add_cks, add_sizes, add_payloads)

        del_rids = np.array(
            [pmap[int(k)] for k in np.concatenate([mod_keys, del_keys])],
            dtype=np.int64)
        # merged-in records count as adds relative to the retained parent
        merge_rids = np.array([rid for k, rid in merged_extra.items()
                               if k not in set(del_keys.tolist())], dtype=np.int64)
        all_adds = np.concatenate([add_rids, merge_rids])

        graph.add_version(vid, parents, all_adds, del_rids)

        # ---- update bookkeeping ----------------------------------------
        cmap = dict(pmap)
        for k, rid in merged_extra.items():
            cmap[int(k)] = rid
        for k in del_keys:
            cmap.pop(int(k), None)
        for k, rid in zip(add_keys.tolist(), add_rids.tolist()):
            cmap[int(k)] = rid
        key_to_rid[vid] = cmap
        head = vid

    return graph


def dataset_stats(graph: VersionGraph) -> Dict[str, float]:
    sizes = graph.store.sizes
    vsz = graph.version_sizes()
    return {
        "versions": graph.num_versions,
        "unique_records": len(graph.store),
        "unique_bytes": int(sizes.sum()),
        "total_bytes": int(sum(vsz.values())),
        "avg_depth": graph.avg_depth(),
        "avg_records_per_version": graph.total_entries() / graph.num_versions,
    }
