"""Sub-chunk construction + transformed version tree (§3.4, Algorithm 5).

Sub-chunks group ≤ k records of one primary key that form a *connected*
subgraph of the version tree (connectivity maximizes delta-compressibility:
"records are more likely to be similar to their parents than their
siblings").  The bottom-up pass keeps, per version, a collection Ψ of pending
same-key record sets; at each version the paper's e(K)/s(K) case analysis
either seals sub-chunks or defers them upward.

The transformed version tree (Example 6) then re-expresses versions over
sub-chunks (each represented by the composite key of its shallowest record)
and deletes versions whose sub-chunk membership duplicates their parent's —
the ordinary partitioners (§3.1–3.3) run unchanged on this derived graph.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import pack_ck_array
from .version_graph import DeltaIds, RecordStore, VersionGraph


# ----------------------------------------------------------- Algorithm 5
def build_subchunks(graph: VersionGraph, k: int) -> List[np.ndarray]:
    """Partition all records into connected same-primary-key groups of ≤ k.

    Returns a list of record-id arrays in tree order (shallowest-origin
    first — the sub-chunk's delta base).  k=1 degenerates to singletons
    (the paper's no-compression case).
    """
    if k <= 1:
        return [np.array([r], dtype=np.int64) for r in range(len(graph.store))]

    store = graph.store
    keys = store.keys()
    origins = store.origin_versions()
    depth = {v: graph.depth(v) for v in graph.versions}

    # records originated per version (merge-carried records belong to their
    # true origin version, where they already entered Ψ)
    orig_at: Dict[int, List[int]] = {v: [] for v in graph.versions}
    for rid in range(len(store)):
        v = int(origins[rid])
        if v in orig_at:
            orig_at[v].append(rid)

    out: List[np.ndarray] = []

    def seal(records: List[int]) -> None:
        rs = sorted(set(records), key=lambda r: (depth[int(origins[r])], r))
        out.append(np.asarray(rs, dtype=np.int64))

    # Ψ per version: pk -> list of pending record-lists
    psi: Dict[int, Dict[int, List[List[int]]]] = {}

    for v in graph.postorder():
        children = graph.tree_children(v)
        own: Dict[int, int] = {}
        for rid in orig_at[v]:
            pk = int(keys[rid])
            if pk in own:           # same pk twice in one version cannot happen
                seal([rid])
                continue
            own[pk] = rid
        sigma: Dict[int, List[List[int]]] = {}
        for c in children:
            for pk, sets in psi.pop(c).items():
                sigma.setdefault(pk, []).extend(sets)
        for pk in own:
            sigma.setdefault(pk, [])

        mine: Dict[int, List[List[int]]] = {}
        for pk, sets in sigma.items():
            e = 1 if pk in own else 0
            s = sum(len(x) for x in sets)
            # seal largest sets until the union could fit in one sub-chunk
            while s + e > k:
                sets.sort(key=len)
                big = sets.pop()
                seal(big)
                s -= len(big)
            if e:
                merged = [own[pk]] + [r for x in sets for r in x]
                if len(merged) == k:
                    seal(merged)
                else:
                    mine[pk] = [merged]
            elif sets:
                mine[pk] = sets      # pass through unmerged (connect at ancestor)
        psi[v] = mine

    for pk, sets in psi.pop(graph.root).items():  # type: ignore[arg-type]
        for x in sets:
            seal(x)
    assert not psi

    # coverage check: every record in exactly one group
    flat = np.concatenate(out) if out else np.empty(0, np.int64)
    assert len(flat) == len(store) and len(np.unique(flat)) == len(store)
    return out


# --------------------------------------------------- transformed version tree
@dataclass
class TransformedDataset:
    tgraph: VersionGraph             # versions over sub-chunk "records"
    groups: List[np.ndarray]         # sub-chunk id -> member record ids
    rec_to_sub: np.ndarray           # record id -> sub-chunk id
    version_alias: Dict[int, int]    # original vid -> surviving tree vid


def build_transformed(graph: VersionGraph, groups: List[np.ndarray],
                      sub_sizes: Optional[np.ndarray] = None) -> TransformedDataset:
    """Build the transformed version tree over sub-chunks (Example 6)."""
    n_sub = len(groups)
    rec_to_sub = np.full(len(graph.store), -1, dtype=np.int64)
    for sid, grp in enumerate(groups):
        rec_to_sub[grp] = sid

    if sub_sizes is None:
        sizes = graph.store.sizes
        sub_sizes = np.array([int(sizes[g].sum()) for g in groups], dtype=np.int64)

    # representative composite key = shallowest member's ck
    rep_cks = np.array([int(graph.store.cks[g[0]]) for g in groups], dtype=np.int64)

    tstore = RecordStore()
    tstore.add_batch(rep_cks, sub_sizes)

    tgraph = VersionGraph(tstore)
    alias: Dict[int, int] = {}
    member_cache: Dict[int, np.ndarray] = {}

    for v in graph.versions:          # parents-before-children
        msub = np.unique(rec_to_sub[graph.members(v)])
        p = graph.tree_parent(v)
        if p is None:
            tgraph.add_root(v, msub)
            alias[v] = v
            member_cache[v] = msub
            continue
        pv = alias[p]
        pm = member_cache[pv]
        if np.array_equal(msub, pm):
            alias[v] = pv             # duplicate version — deleted (Ex. 6)
            continue
        adds = np.setdiff1d(msub, pm, assume_unique=True)
        dels = np.setdiff1d(pm, msub, assume_unique=True)
        tgraph.add_version(v, [pv], adds, dels)
        alias[v] = v
        member_cache[v] = msub

    return TransformedDataset(tgraph=tgraph, groups=groups,
                              rec_to_sub=rec_to_sub, version_alias=alias)


def compose_record_to_chunk(tds: TransformedDataset,
                            sub_to_chunk: np.ndarray) -> np.ndarray:
    """record -> chunk through the sub-chunk assignment."""
    return sub_to_chunk[tds.rec_to_sub]


def compressed_subchunk_sizes(graph: VersionGraph,
                              groups: List[np.ndarray]) -> np.ndarray:
    """Actual stored size per sub-chunk (XOR-delta + zlib), requires payloads."""
    import zlib

    from ..kernels import ops as kops
    sizes = np.zeros(len(groups), dtype=np.int64)
    origins = graph.store.origin_versions()
    depth = {v: graph.depth(v) for v in graph.versions}
    for sid, grp in enumerate(groups):
        ordered = sorted(grp.tolist(), key=lambda r: (depth[int(origins[r])], r))
        payloads = [graph.store.payload(r) for r in ordered]
        pieces = [payloads[0]]
        for i in range(1, len(ordered)):
            # delta against nearest in-group ancestor (tree order ⇒ previous
            # member on the path); fall back to group base
            w = max(len(payloads[i - 1]), len(payloads[i]))
            d, _ = kops.xor_delta_bytes(payloads[i - 1].ljust(w, b"\0"),
                                        payloads[i].ljust(w, b"\0"))
            pieces.append(d)
        sizes[sid] = len(zlib.compress(b"".join(pieces), 6))
    return sizes
