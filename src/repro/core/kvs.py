"""Backend key-value store abstraction (§2.4).

RStore assumes only get/put/multiget/multiput/multidelete from the backend —
the :class:`Backend` protocol.  All directions are batched: ``multiget`` is
one read round trip, ``multiput`` one write round trip (the §2.3 insight —
few large requests beat many small ones — applied symmetrically; the write
side is what the group-committing :class:`~repro.core.ingest.WriteSession`
rides on), and ``multidelete`` one round trip reclaiming a batch of
superseded keys (what :class:`~repro.core.compact.Compactor` GC rides on).
Three implementations:

- :class:`InMemoryKVS` — host dict with request/byte counters and a simple
  latency model (per-query overhead + bandwidth), used to reproduce the §2.3
  "too many queries" experiment without a Cassandra cluster.

- :class:`ShardedDeviceKVS` — the TPU-native realization: a fixed-slot
  ``uint32[n_slots, slot_words]`` table sharded across the JAX mesh's
  devices; ``multiget`` is ONE jitted batched gather (the gather's collective
  traffic scales with span, which the roofline section measures).

- :class:`ShardedKVS` — the *distributed* layer the paper assumes: a router
  that hash-partitions the keyspace over N inner backends and fans
  ``multiget``/``multiput`` out as one round trip per shard touched.

The replication & fault-tolerance layer lives in :mod:`repro.core.replica`
and composes with all of the above through the same protocol:

- :class:`~repro.core.replica.ReplicatedKVS` — an N-way replica group
  (quorum writes, per-batch read failover, read-repair) that slots in as a
  ``ShardedKVS`` shard via ``make_sharded_backend(..., replication_factor=R)``.

- :class:`~repro.core.replica.FaultInjectingKVS` — a wrapper with a
  deterministic seeded fault schedule (transient errors, timeouts, hard
  ``kill()``) raising the :class:`~repro.core.replica.BackendUnavailable`
  taxonomy, for testing every degraded-mode path.

A missing key raises ``KeyError`` naming the key — a *data-level* miss,
deliberately distinct from ``BackendUnavailable`` so failover logic never
re-routes a legitimate miss.  ``scan`` (one round trip returning every
stored item) is the recovery primitive replica rebuilds ride on.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .costmodel import BANDWIDTH_BPS, PER_QUERY_S


@dataclass
class KVSStats:
    n_queries: int = 0          # read round-trips to the backend
    n_values: int = 0           # values fetched
    bytes_fetched: int = 0
    n_put_queries: int = 0      # write round-trips (each put / multiput)
    n_values_put: int = 0       # values stored
    bytes_stored: int = 0
    n_delete_queries: int = 0   # delete round-trips (each delete / multidelete)
    n_keys_deleted: int = 0     # keys removed
    n_retries: int = 0          # op retries after transient faults/timeouts
    n_failovers: int = 0        # replica read attempts that failed over
    simulated_backoff_seconds: float = 0.0  # backoff the retries would sleep
    n_cache_hits: int = 0       # reads served by a CachingKVS layer
    n_cache_misses: int = 0     # reads a CachingKVS had to forward down
    bytes_served_from_cache: int = 0  # payload served at memory speed
    n_flush_batches: int = 0    # BackgroundFlusher drains that committed
    n_versions_staged: int = 0  # versions staged through async ingest
    max_observed_lag: int = 0   # high-water committed-but-not-durable count

    def simulated_seconds(self, per_query_s: float = PER_QUERY_S,
                          bandwidth_Bps: float = BANDWIDTH_BPS) -> float:
        """Cassandra-like read cost model: per-request overhead + transfer."""
        return self.n_queries * per_query_s + self.bytes_fetched / bandwidth_Bps

    def simulated_write_seconds(self, per_query_s: float = PER_QUERY_S,
                                bandwidth_Bps: float = BANDWIDTH_BPS) -> float:
        """Same cost model for the write side.  Deletes carry payload-free
        requests: per-query overhead only."""
        return ((self.n_put_queries + self.n_delete_queries) * per_query_s
                + self.bytes_stored / bandwidth_Bps)

    def reset(self) -> None:
        for f in self._FIELDS:
            setattr(self, f, 0)

    def snapshot(self) -> "KVSStats":
        """Copy of the current counters (pair with :meth:`restore` to run
        bookkeeping traffic without polluting stats a caller is
        accumulating)."""
        return KVSStats(**{f: getattr(self, f) for f in self._FIELDS})

    def restore(self, saved: "KVSStats") -> None:
        for f in self._FIELDS:
            setattr(self, f, getattr(saved, f))

    @staticmethod
    def merged(parts: Iterable["KVSStats"]) -> "KVSStats":
        """Aggregate of several counters (e.g. per-shard stats)."""
        out = KVSStats()
        for p in parts:
            for f in KVSStats._FIELDS:
                setattr(out, f, getattr(out, f) + getattr(p, f))
        return out


# Derived, not hand-maintained: reset/snapshot/restore/merged iterate this in
# declaration order, so adding a counter to the dataclass is the whole change.
KVSStats._FIELDS = tuple(f.name for f in dataclasses.fields(KVSStats))


class Backend(Protocol):
    """What RStore requires of the distributed KV store (§2.4): batched reads
    AND batched writes, each one round trip per call.  ``multidelete`` is the
    maintenance-path primitive (compaction GC): one round trip removing a
    whole batch of superseded keys."""

    stats: KVSStats

    def put(self, key: str, value: bytes) -> None: ...
    def get(self, key: str) -> bytes: ...
    def multiget(self, keys: Sequence[str]) -> List[bytes]: ...
    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None: ...
    def delete(self, key: str) -> None: ...
    def multidelete(self, keys: Sequence[str]) -> None: ...
    def scan(self) -> List[Tuple[str, bytes]]: ...
    def __contains__(self, key: str) -> bool: ...


# Back-compat alias: the pre-write-path name for the protocol.
KVS = Backend


class InMemoryKVS:
    def __init__(self) -> None:
        self._d: Dict[str, bytes] = {}
        self.stats = KVSStats()

    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def _lookup(self, key: str) -> bytes:
        """A miss names the missing key — a *data-level* KeyError, so
        failover logic (and users) can tell "missing key" from "shard
        down" (:class:`repro.core.replica.BackendUnavailable`)."""
        try:
            return self._d[key]
        except KeyError:
            raise KeyError(f"InMemoryKVS: missing key {key!r}") from None

    def get(self, key: str) -> bytes:
        v = self._lookup(key)
        self.stats.n_queries += 1
        self.stats.n_values += 1
        self.stats.bytes_fetched += len(v)
        return v

    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        """One batched round-trip (the chunked design needs only this).

        An empty batch costs nothing: no backend call, no stats."""
        if not keys:
            return []
        vs = [self._lookup(k) for k in keys]
        self.stats.n_queries += 1
        self.stats.n_values += len(vs)
        self.stats.bytes_fetched += sum(len(v) for v in vs)
        return vs

    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        """One batched write round-trip (the group-commit primitive)."""
        if not items:
            return
        for k, v in items:
            self._d[k] = v
        self.stats.n_put_queries += 1
        self.stats.n_values_put += len(items)
        self.stats.bytes_stored += sum(len(v) for _, v in items)

    def multiget_naive(self, keys: Sequence[str]) -> List[bytes]:
        """Per-key round-trips — the §2.3 baseline behaviour."""
        return [self.get(k) for k in keys]

    def delete(self, key: str) -> None:
        self.multidelete([key])

    def multidelete(self, keys: Sequence[str]) -> None:
        """One batched delete round-trip (the compaction GC primitive).

        An empty batch costs nothing, matching the empty multiget/multiput
        convention.  Deleting an absent key raises — the maintenance path
        only ever deletes keys it owns, so a miss is an index/storage
        divergence bug worth failing loudly on."""
        if not keys:
            return
        for k in keys:
            if k not in self._d:
                raise KeyError(f"InMemoryKVS: missing key {k!r}")
            del self._d[k]
        self.stats.n_delete_queries += 1
        self.stats.n_keys_deleted += len(keys)

    def scan(self) -> List[Tuple[str, bytes]]:
        """Every stored (key, value) in one round trip — the recovery
        primitive (:class:`repro.core.replica.RecoveryManager` rebuilds a
        lost replica from one survivor scan)."""
        items = list(self._d.items())
        self.stats.n_queries += 1
        self.stats.n_values += len(items)
        self.stats.bytes_fetched += sum(len(v) for _, v in items)
        return items

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def total_stored_bytes(self) -> int:
        return sum(len(v) for v in self._d.values())


# ---------------------------------------------------------------- shard router
class ShardedKVS:
    """Hash-partitioned router over N inner backends.

    The keyspace is split by a stable hash (crc32 of the key); ``multiget``
    and ``multiput`` fan out per shard — one inner round trip per shard
    touched — and results are reassembled in request order.  ``stats`` on the
    router counts those per-shard round trips (a batch spanning 4 shards is
    4 round trips: the shards are independent servers); per-shard counters
    stay on the inner backends (:meth:`shard_stats`).
    """

    def __init__(self, shards: Sequence[Backend]) -> None:
        if not shards:
            raise ValueError("ShardedKVS needs at least one shard")
        self.shards: List[Backend] = list(shards)
        self.stats = KVSStats()

    def shard_of(self, key: str) -> int:
        return zlib.crc32(key.encode()) % len(self.shards)

    # ------------------------------------------------------------------ reads
    def get(self, key: str) -> bytes:
        v = self.shards[self.shard_of(key)].get(key)
        self.stats.n_queries += 1
        self.stats.n_values += 1
        self.stats.bytes_fetched += len(v)
        return v

    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        if not keys:
            return []
        groups: Dict[int, List[int]] = {}
        for i, k in enumerate(keys):
            groups.setdefault(self.shard_of(k), []).append(i)
        out: List[Optional[bytes]] = [None] * len(keys)
        for s, idxs in groups.items():
            vals = self.shards[s].multiget([keys[i] for i in idxs])
            for i, v in zip(idxs, vals):
                out[i] = v
        self.stats.n_queries += len(groups)
        self.stats.n_values += len(keys)
        self.stats.bytes_fetched += sum(len(v) for v in out)  # type: ignore
        return out  # type: ignore[return-value]

    # ----------------------------------------------------------------- writes
    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        """One round trip per shard touched — a whole group commit lands in
        O(shards) backend writes however many chunks it carries."""
        if not items:
            return
        groups: Dict[int, List[Tuple[str, bytes]]] = {}
        for kv in items:
            groups.setdefault(self.shard_of(kv[0]), []).append(kv)
        for s, sub in groups.items():
            self.shards[s].multiput(sub)
        self.stats.n_put_queries += len(groups)
        self.stats.n_values_put += len(items)
        self.stats.bytes_stored += sum(len(v) for _, v in items)

    # ---------------------------------------------------------------- deletes
    def delete(self, key: str) -> None:
        self.multidelete([key])

    def multidelete(self, keys: Sequence[str]) -> None:
        """One delete round trip per shard touched; an empty key list skips
        the backend entirely (the empty-batch convention)."""
        if not keys:
            return
        groups: Dict[int, List[str]] = {}
        for k in keys:
            groups.setdefault(self.shard_of(k), []).append(k)
        for s, sub in groups.items():
            self.shards[s].multidelete(sub)
        self.stats.n_delete_queries += len(groups)
        self.stats.n_keys_deleted += len(keys)

    # ------------------------------------------------------------------ misc
    def scan(self) -> List[Tuple[str, bytes]]:
        """Every stored item — one scan round trip per shard."""
        out: List[Tuple[str, bytes]] = []
        for s in self.shards:
            items = s.scan()
            out.extend(items)
            self.stats.n_queries += 1
            self.stats.n_values += len(items)
            self.stats.bytes_fetched += sum(len(v) for _, v in items)
        return out

    def __contains__(self, key: str) -> bool:
        return key in self.shards[self.shard_of(key)]

    def shard_stats(self) -> List[KVSStats]:
        """Per-shard counters, in shard order."""
        return [s.stats for s in self.shards]

    def aggregate_shard_stats(self) -> KVSStats:
        return KVSStats.merged(self.shard_stats())

    def total_stored_bytes(self) -> int:
        return sum(s.total_stored_bytes() for s in self.shards
                   if hasattr(s, "total_stored_bytes"))


class ShardedDeviceKVS:
    """Fixed-slot store living as a device-sharded JAX array.

    Values are padded into ``slot_bytes`` slots; longer values span
    consecutive slots.  ``multiget`` issues a single ``jnp.take`` over the
    sharded table — on a real mesh this is a batched all-gather whose volume
    is span × slot size.  Host-side writes are buffered and flushed in one
    device_put; ``multiput`` stages a whole group commit as one write round
    trip (ingest is batched, mirroring §4's delta store).  Freed extents
    (relocated or shrunk values) go on a first-fit free list so overwrites
    never leak slots.
    """

    def __init__(self, slot_bytes: int = 1 << 16, n_slots: int = 1024,
                 mesh=None) -> None:
        import jax
        import jax.numpy as jnp
        self._jax = jax
        self._jnp = jnp
        self.slot_bytes = int(slot_bytes)
        self.slot_words = self.slot_bytes // 4
        self.mesh = mesh
        self._table = None                       # device array, lazily built
        self._host = np.zeros((n_slots, self.slot_words), dtype=np.uint32)
        self._dirty = True
        self._next_slot = 0
        self._free: List[Tuple[int, int]] = []   # (slot, n) reclaimed extents
        self._dir: Dict[str, Tuple[int, int, int]] = {}  # key -> (slot, n, len)
        self.stats = KVSStats()
        self._gather = jax.jit(lambda t, idx: jnp.take(t, idx, axis=0))

    # ------------------------------------------------------------------ put
    def put(self, key: str, value: bytes) -> None:
        self.multiput([(key, value)])

    def multiput(self, items: Sequence[Tuple[str, bytes]]) -> None:
        """Stage a batch of writes; the (deferred) device sync is one
        transfer however many values the batch carries."""
        if not items:
            return
        for k, v in items:
            self._store_one(k, v)
        self.stats.n_put_queries += 1
        self.stats.n_values_put += len(items)
        self.stats.bytes_stored += sum(len(v) for _, v in items)

    def _store_one(self, key: str, value: bytes) -> None:
        n = max(1, math.ceil(len(value) / self.slot_bytes))
        if key in self._dir:
            slot, old_n, _ = self._dir[key]
            if old_n < n:                       # relocate; reclaim old extent
                self._release(slot, old_n)
                slot = self._alloc(n)
            elif old_n > n:                     # shrink in place; free tail
                self._release(slot + n, old_n - n)
        else:
            slot = self._alloc(n)
        buf = np.zeros(n * self.slot_words, dtype=np.uint32)
        raw = np.frombuffer(value.ljust(n * self.slot_bytes, b"\0"), dtype=np.uint32)
        buf[:] = raw
        self._host[slot:slot + n] = buf.reshape(n, self.slot_words)
        self._dir[key] = (slot, n, len(value))
        self._dirty = True

    def _release(self, slot: int, n: int) -> None:
        """Return an extent to the free list, coalescing adjacent extents —
        without merging, a repeatedly-growing value would fragment its old
        extents into ever-too-small holes and never reuse them.  An extent
        ending at the high-water mark shrinks it instead."""
        if n <= 0:
            return
        self._free.append((slot, n))
        self._coalesce()

    def _coalesce(self) -> None:
        self._free.sort()
        merged: List[Tuple[int, int]] = []
        for s, m in self._free:
            if merged and merged[-1][0] + merged[-1][1] == s:
                merged[-1] = (merged[-1][0], merged[-1][1] + m)
            else:
                merged.append((s, m))
        while merged and merged[-1][0] + merged[-1][1] == self._next_slot:
            self._next_slot = merged[-1][0]
            merged.pop()
        self._free = merged

    def _alloc(self, n: int) -> int:
        # first fit over the free list before bumping the high-water mark
        for i, (slot, m) in enumerate(self._free):
            if m >= n:
                if m == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (slot + n, m - n)
                return slot
        slot = self._next_slot
        self._next_slot += n
        while self._next_slot > len(self._host):
            self._host = np.concatenate(
                [self._host, np.zeros_like(self._host)], axis=0)
        return slot

    @property
    def free_slots(self) -> int:
        """Reclaimed-but-unreused slots (leak detector for tests)."""
        return sum(m for _, m in self._free)

    @property
    def high_water_slots(self) -> int:
        return self._next_slot

    def _sync(self):
        if self._dirty or self._table is None:
            jnp = self._jnp
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                ndev = math.prod(self.mesh.devices.shape)
                pad = (-len(self._host)) % ndev
                host = np.pad(self._host, ((0, pad), (0, 0)))
                sh = NamedSharding(self.mesh, P(tuple(self.mesh.axis_names), None))
                self._table = self._jax.device_put(host, sh)
            else:
                self._table = jnp.asarray(self._host)
            self._dirty = False
        return self._table

    # ------------------------------------------------------------------ get
    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        if not keys:                      # empty batch: no gather, no stats
            return []
        table = self._sync()
        metas = [self._dir[k] for k in keys]
        idx = np.concatenate([np.arange(s, s + n) for s, n, _ in metas])
        rows = np.asarray(self._gather(table, self._jnp.asarray(idx)))
        out: List[bytes] = []
        off = 0
        for _, n, ln in metas:
            out.append(rows[off:off + n].tobytes()[:ln])
            off += n
        self.stats.n_queries += 1
        self.stats.n_values += len(keys)
        self.stats.bytes_fetched += int(rows.nbytes)
        return out

    def get(self, key: str) -> bytes:
        return self.multiget([key])[0]

    # --------------------------------------------------------------- delete
    def delete(self, key: str) -> None:
        self.multidelete([key])

    def multidelete(self, keys: Sequence[str]) -> None:
        """Remove a batch of keys in one round trip, returning their slot
        extents to the first-fit free list (coalesced via ``_release``) so
        compaction GC actually shrinks the device footprint.  Absent keys
        raise; an empty batch costs nothing."""
        if not keys:
            return
        for k in keys:
            slot, n, _ = self._dir.pop(k)
            if n > 0:
                self._free.append((slot, n))
        self._coalesce()            # one sort+merge for the whole batch
        self.stats.n_delete_queries += 1
        self.stats.n_keys_deleted += len(keys)
        self._dirty = True

    def scan(self) -> List[Tuple[str, bytes]]:
        """Every stored item via the one-gather ``multiget`` machinery —
        one round trip (the replica-rebuild primitive)."""
        keys = list(self._dir)
        return list(zip(keys, self.multiget(keys)))

    def __contains__(self, key: str) -> bool:
        return key in self._dir

    def total_stored_bytes(self) -> int:
        return sum(ln for _, _, ln in self._dir.values())
