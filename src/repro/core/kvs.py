"""Backend key-value store abstraction (§2.4).

RStore assumes only get/put/multiget from the backend.  Two implementations:

- :class:`InMemoryKVS` — host dict with request/byte counters and a simple
  latency model (per-query overhead + bandwidth), used to reproduce the §2.3
  "too many queries" experiment without a Cassandra cluster.

- :class:`ShardedDeviceKVS` — the TPU-native realization: a fixed-slot
  ``uint32[n_slots, slot_words]`` table sharded across the JAX mesh's
  devices; ``multiget`` is ONE jitted batched gather (the chunking insight:
  few large fetches beat many small ones — the gather's collective traffic
  scales with span, which the roofline section measures).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

import numpy as np


@dataclass
class KVSStats:
    n_queries: int = 0          # round-trips to the backend
    n_values: int = 0           # values fetched
    bytes_fetched: int = 0
    bytes_stored: int = 0

    def simulated_seconds(self, per_query_s: float = 5e-4,
                          bandwidth_Bps: float = 200e6) -> float:
        """Cassandra-like cost model: fixed per-request overhead + transfer."""
        return self.n_queries * per_query_s + self.bytes_fetched / bandwidth_Bps

    def reset(self) -> None:
        self.n_queries = self.n_values = 0
        self.bytes_fetched = self.bytes_stored = 0

    def snapshot(self) -> "KVSStats":
        """Copy of the current counters (pair with :meth:`restore` to run
        bookkeeping traffic — e.g. chunk sizing — without polluting stats a
        caller is accumulating)."""
        return KVSStats(n_queries=self.n_queries, n_values=self.n_values,
                        bytes_fetched=self.bytes_fetched,
                        bytes_stored=self.bytes_stored)

    def restore(self, saved: "KVSStats") -> None:
        self.n_queries = saved.n_queries
        self.n_values = saved.n_values
        self.bytes_fetched = saved.bytes_fetched
        self.bytes_stored = saved.bytes_stored


class KVS(Protocol):
    stats: KVSStats

    def put(self, key: str, value: bytes) -> None: ...
    def get(self, key: str) -> bytes: ...
    def multiget(self, keys: Sequence[str]) -> List[bytes]: ...
    def __contains__(self, key: str) -> bool: ...


class InMemoryKVS:
    def __init__(self) -> None:
        self._d: Dict[str, bytes] = {}
        self.stats = KVSStats()

    def put(self, key: str, value: bytes) -> None:
        self._d[key] = value
        self.stats.bytes_stored += len(value)

    def get(self, key: str) -> bytes:
        v = self._d[key]
        self.stats.n_queries += 1
        self.stats.n_values += 1
        self.stats.bytes_fetched += len(v)
        return v

    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        """One batched round-trip (the chunked design needs only this)."""
        vs = [self._d[k] for k in keys]
        self.stats.n_queries += 1
        self.stats.n_values += len(vs)
        self.stats.bytes_fetched += sum(len(v) for v in vs)
        return vs

    def multiget_naive(self, keys: Sequence[str]) -> List[bytes]:
        """Per-key round-trips — the §2.3 baseline behaviour."""
        return [self.get(k) for k in keys]

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def total_stored_bytes(self) -> int:
        return sum(len(v) for v in self._d.values())


class ShardedDeviceKVS:
    """Fixed-slot store living as a device-sharded JAX array.

    Values are padded into ``slot_bytes`` slots; longer values span
    consecutive slots.  ``multiget`` issues a single ``jnp.take`` over the
    sharded table — on a real mesh this is a batched all-gather whose volume
    is span × slot size.  Host-side writes are buffered and flushed in one
    device_put (ingest is batched, mirroring §4's delta store).
    """

    def __init__(self, slot_bytes: int = 1 << 16, n_slots: int = 1024,
                 mesh=None) -> None:
        import jax
        import jax.numpy as jnp
        self._jax = jax
        self._jnp = jnp
        self.slot_bytes = int(slot_bytes)
        self.slot_words = self.slot_bytes // 4
        self.mesh = mesh
        self._table = None                       # device array, lazily built
        self._host = np.zeros((n_slots, self.slot_words), dtype=np.uint32)
        self._dirty = True
        self._next_slot = 0
        self._dir: Dict[str, Tuple[int, int, int]] = {}  # key -> (slot, n, len)
        self.stats = KVSStats()
        self._gather = jax.jit(lambda t, idx: jnp.take(t, idx, axis=0))

    # ------------------------------------------------------------------ put
    def put(self, key: str, value: bytes) -> None:
        n = max(1, math.ceil(len(value) / self.slot_bytes))
        if key in self._dir:
            slot, old_n, _ = self._dir[key]
            if old_n < n:                       # relocate
                slot = self._alloc(n)
        else:
            slot = self._alloc(n)
        buf = np.zeros(n * self.slot_words, dtype=np.uint32)
        raw = np.frombuffer(value.ljust(n * self.slot_bytes, b"\0"), dtype=np.uint32)
        buf[:] = raw
        self._host[slot:slot + n] = buf.reshape(n, self.slot_words)
        self._dir[key] = (slot, n, len(value))
        self._dirty = True
        self.stats.bytes_stored += len(value)

    def _alloc(self, n: int) -> int:
        slot = self._next_slot
        self._next_slot += n
        while self._next_slot > len(self._host):
            self._host = np.concatenate(
                [self._host, np.zeros_like(self._host)], axis=0)
        return slot

    def _sync(self):
        if self._dirty or self._table is None:
            jnp = self._jnp
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                ndev = math.prod(self.mesh.devices.shape)
                pad = (-len(self._host)) % ndev
                host = np.pad(self._host, ((0, pad), (0, 0)))
                sh = NamedSharding(self.mesh, P(tuple(self.mesh.axis_names), None))
                self._table = self._jax.device_put(host, sh)
            else:
                self._table = jnp.asarray(self._host)
            self._dirty = False
        return self._table

    # ------------------------------------------------------------------ get
    def multiget(self, keys: Sequence[str]) -> List[bytes]:
        table = self._sync()
        metas = [self._dir[k] for k in keys]
        idx = np.concatenate([np.arange(s, s + n) for s, n, _ in metas]) \
            if metas else np.zeros(0, np.int64)
        rows = np.asarray(self._gather(table, self._jnp.asarray(idx)))
        out: List[bytes] = []
        off = 0
        for _, n, ln in metas:
            out.append(rows[off:off + n].tobytes()[:ln])
            off += n
        self.stats.n_queries += 1
        self.stats.n_values += len(keys)
        self.stats.bytes_fetched += int(rows.nbytes)
        return out

    def get(self, key: str) -> bytes:
        return self.multiget([key])[0]

    def __contains__(self, key: str) -> bool:
        return key in self._dir
