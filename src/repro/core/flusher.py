"""Async ingest: a background group-flusher with bounded lag (§6).

``WriteSession.flush`` group-commits synchronously — commit latency is
bound to backend write round trips, and K concurrent sessions pay K
separate group flushes (K·S round trips on S shards).  This module
decouples the two, in the buffering discipline the versioned-dictionary
line of work studies (Byde & Twigg — the update/query trade-off hinges on
exactly this staging buffer) and with the bounded-staleness semantics the
multi-version coding literature motivates (Ali & Cadambe — tolerating a
bounded lag between *committed* and *durable* versions):

- **Double-buffered staging.**  The *active* buffer is the store's delta
  area (``rs.pending``): ``commit()`` from any number of open
  :class:`~repro.core.ingest.WriteSession`\\ s stages versions there at
  ZERO backend round trips.  A drain swaps the active buffer into the
  *shadow* buffer, prepares its physical writes (chunking, map rebuilds,
  index postings — all in-memory), and commits them in ONE ``multiput``.
  New commits land in the (now empty) active buffer while the shadow is
  in flight, so staging is never blocked on the backend.

- **Watermark triggers.**  A drain fires when the active buffer reaches
  ``max_staged_versions`` or ``max_staged_bytes``, when the oldest staged
  version is ``max_staged_age`` clock steps old, or explicitly via
  ``rs.barrier()``.  Between drains the store runs with *bounded lag*:
  ``staleness_lag`` committed-but-not-yet-durable versions.

- **Cross-session batching.**  One drain commits every staged version
  from every session in one group commit: K sessions on S shards cost
  ≤S write round trips, not K·S.

- **Replay-idempotent failure handling.**  The drain's ``multiput`` runs
  under a :class:`~repro.core.replica.RetryPolicy`.  If retries are
  exhausted the prepared writes stay in the shadow buffer and the staged
  versions SURVIVE: the next drain appends any newly staged work after
  them and re-puts the whole batch.  ``multiput`` is idempotent and
  later duplicates of a key win, so a :class:`BackendTimeout` whose
  write actually applied is re-put harmlessly and newer chunk-map blobs
  supersede stale ones.

- **Virtual step clock.**  The flusher is event-driven off an integer
  step counter (every stage/tick/drain advances it) — no threads, no
  real sleeps, same discipline as ``RetryPolicy``'s simulated backoff.
  Every interleaving of stage/drain/read/compact/kill is deterministic
  and replayable, which the interleaving test harness exploits.

Reads get explicit semantics: ``rs.snapshot()`` (mode ``"fresh"``)
drains first — read-your-writes — while ``rs.snapshot(mode="pinned")``
pins the last durable state and reports its ``staleness_lag``.
Maintenance (``build()`` / ``compact()`` / ``retain()``) takes a drain
barrier before touching layout, so replayed writes never cross a
re-partition.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from . import costmodel
from .replica import RetryPolicy

# Adaptive watermark model (costmodel-derived defaults): a drain should be
# big enough that its transfer time dominates the per-round-trip overhead by
# this factor — bytes = _AMORTIZE_ROUND_TRIPS × PER_QUERY_S × BANDWIDTH_BPS.
_AMORTIZE_ROUND_TRIPS = 16
# Version watermark adapts to the observed average staged-version size
# (byte watermark ÷ avg bytes), clamped so tiny versions can't stage
# unboundedly and huge ones still batch a little.
_MIN_ADAPTIVE_VERSIONS = 8
_MAX_ADAPTIVE_VERSIONS = 8192
_DEFAULT_ADAPTIVE_VERSIONS = 64     # before any version size is observed


@dataclass
class DrainReport:
    """What one :meth:`BackgroundFlusher.drain` did.

    ``write_round_trips`` is measured against the top-of-stack stats
    (retries included), so the ≤S-round-trips contract is assertable
    directly.  An empty drain returns the all-zero report without
    touching the backend — the empty-multiput convention."""

    n_versions: int = 0          # versions made durable by this drain
    n_writes: int = 0            # (key, blob) pairs in the committed batch
    write_round_trips: int = 0   # backend write round trips the drain cost
    replayed: bool = False       # batch included writes from a failed drain
    step: int = 0                # virtual clock at completion


class BackgroundFlusher:
    """Background group-flusher: double-buffered staging with bounded lag.

    Attach with :meth:`~repro.core.ingest.RStore.attach_flusher`; the
    store then allows any number of concurrent ``writer()`` sessions,
    whose commits stage at zero round trips and drain together.  Detach
    (and drain) with :meth:`close`.

    Watermarks: ``max_staged_versions`` / ``max_staged_bytes`` bound the
    active buffer; ``max_staged_age`` (in virtual clock steps, ``None``
    disables) bounds how long the oldest staged version may wait.  The
    lag between committed and durable state is therefore bounded by
    whichever watermark fires first — `staleness_lag` reports it live.

    By default both watermarks are *adaptive*, derived from the cost model
    instead of fixed constants: the byte watermark stages enough data that
    one drain's transfer time amortizes its per-round-trip overhead
    (``costmodel.PER_QUERY_S`` / ``BANDWIDTH_BPS``), and the version
    watermark re-derives from the byte watermark at the observed average
    staged-version size.  Passing an explicit value pins that watermark
    and disables its adaptation.  ``watermarks()`` (surfaced in
    ``storage_stats()["ingest"]``) reports the effective values.

    Online chunking is k=1 only (same restriction as ``flush()``), so
    attaching to a k>1 store raises."""

    def __init__(self, rs, max_staged_versions: Optional[int] = None,
                 max_staged_bytes: Optional[int] = None,
                 max_staged_age: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None) -> None:
        if rs.config.k > 1:
            raise ValueError(
                "BackgroundFlusher needs k == 1 — the online chunking path "
                "cannot re-group sub-chunks (use build() for k > 1 stores)")
        if max_staged_versions is not None and max_staged_versions < 1:
            raise ValueError("max_staged_versions must be >= 1")
        self.rs = rs
        self._adaptive_versions = max_staged_versions is None
        self._adaptive_bytes = max_staged_bytes is None
        self.max_staged_bytes = (
            int(_AMORTIZE_ROUND_TRIPS * costmodel.PER_QUERY_S
                * costmodel.BANDWIDTH_BPS)
            if self._adaptive_bytes else int(max_staged_bytes))
        self.max_staged_versions = (_DEFAULT_ADAPTIVE_VERSIONS
                                    if self._adaptive_versions
                                    else int(max_staged_versions))
        # observed staged-version sizes, across drains (adaptation input)
        self._obs_versions = 0
        self._obs_bytes = 0
        self.max_staged_age = (None if max_staged_age is None
                               else int(max_staged_age))
        self.retry = retry or RetryPolicy()
        self.step = 0                       # virtual clock (event-driven)
        # active buffer: mirrors rs.pending 1:1 — (vid, nbytes, staged_step)
        self._active: List[Tuple[int, int, int]] = []
        self._active_bytes = 0
        # shadow buffer: versions whose physical writes are prepared but
        # not yet acked, plus those writes (the replay list)
        self._shadow_vids: List[int] = []
        self._replay: List[Tuple[str, bytes]] = []
        self._closed = False
        # adopt versions already staged synchronously (their byte sizes
        # were not observed at stage time; they count toward the version
        # watermark and the lag, with 0 recorded bytes)
        for vid in rs.pending:
            self._active.append((vid, 0, self.step))

    # -------------------------------------------------------------- state
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def staged_versions(self) -> int:
        """Versions in the active buffer (not yet prepared)."""
        return len(self._active)

    @property
    def staged_bytes(self) -> int:
        return self._active_bytes

    @property
    def staleness_lag(self) -> int:
        """Committed-but-not-durable versions: active + shadow buffers."""
        return len(self._active) + len(self._shadow_vids)

    @property
    def has_unacked_writes(self) -> bool:
        """True after a failed drain: prepared writes await replay, so the
        in-memory layout is ahead of the durable state."""
        return bool(self._replay)

    def watermarks(self) -> Dict[str, object]:
        """The effective drain thresholds and where they came from
        (``storage_stats()["ingest"]["watermarks"]``)."""
        return {
            "max_staged_versions": self.max_staged_versions,
            "max_staged_bytes": self.max_staged_bytes,
            "max_staged_age": self.max_staged_age,
            "adaptive_versions": self._adaptive_versions,
            "adaptive_bytes": self._adaptive_bytes,
            "observed_avg_version_bytes": (
                int(self._obs_bytes / self._obs_versions)
                if self._obs_versions else 0),
        }

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                "BackgroundFlusher is closed; attach_flusher() again")

    # ------------------------------------------------------------ staging
    def on_stage(self, vid: int, nbytes: int) -> None:
        """Hook called by the store for every staged version (the commit
        itself already landed in ``rs.pending`` — the active buffer)."""
        self._check_open()
        self.step += 1
        self._active.append((vid, int(nbytes), self.step))
        self._active_bytes += int(nbytes)
        if nbytes > 0:
            self._obs_versions += 1
            self._obs_bytes += int(nbytes)
            if self._adaptive_versions:
                avg = self._obs_bytes / self._obs_versions
                self.max_staged_versions = min(
                    _MAX_ADAPTIVE_VERSIONS,
                    max(_MIN_ADAPTIVE_VERSIONS,
                        int(self.max_staged_bytes // max(avg, 1.0))))
        stats = self.rs.kvs.stats
        stats.n_versions_staged += 1
        if self.staleness_lag > stats.max_observed_lag:
            stats.max_observed_lag = self.staleness_lag
        self._maybe_drain()

    def tick(self, n: int = 1) -> None:
        """Advance the virtual clock by ``n`` steps (an external event:
        a request arrived, a session closed...).  May fire the age
        watermark."""
        self._check_open()
        self.step += int(n)
        self._maybe_drain()

    def _maybe_drain(self) -> None:
        if len(self._active) >= self.max_staged_versions:
            self.drain()
        elif self._active_bytes >= self.max_staged_bytes:
            self.drain()
        elif (self.max_staged_age is not None and self._active
              and self.step - self._active[0][2] >= self.max_staged_age):
            self.drain()

    # ------------------------------------------------------------- drain
    def drain(self) -> DrainReport:
        """Swap buffers and group-commit everything staged: ONE
        ``multiput`` for all sessions' versions plus any replay from a
        previously failed drain.  Empty drain = all-zero report, zero
        round trips, no stats noise.  On backend failure (retries
        exhausted) the prepared writes and staged versions survive for
        the next drain; the exception propagates."""
        self._check_open()
        rs = self.rs
        if not rs.pending and not self._replay:
            return DrainReport(step=self.step)
        self.step += 1
        replayed = bool(self._replay)
        if rs.pending:
            batch = list(rs.pending)
            rs.pending = []
            self._shadow_vids.extend(batch)
            # newly prepared writes go AFTER any replay: within one
            # multiput later duplicates win, so fresher chunk-map/posting
            # blobs supersede the stale copies from the failed attempt
            self._replay.extend(rs._prepare_flush_writes(batch))
        self._active = []
        self._active_bytes = 0
        stats = rs.kvs.stats
        p0 = stats.n_put_queries
        self.retry.call(lambda: rs.kvs.multiput(self._replay), stats)
        report = DrainReport(
            n_versions=len(self._shadow_vids),
            n_writes=len(self._replay),
            write_round_trips=stats.n_put_queries - p0,
            replayed=replayed,
            step=self.step)
        stats.n_flush_batches += 1
        self._shadow_vids = []
        self._replay = []
        rs._flushed_versions = rs.graph.num_versions
        return report

    # ------------------------------------------------------------- close
    def close(self) -> Optional[DrainReport]:
        """Drain outstanding work and detach from the store (which
        returns to synchronous one-writer semantics).  Idempotent:
        a second close is a no-op returning ``None``."""
        if self._closed:
            return None
        report = self.drain()
        self._closed = True
        if self.rs._flusher is self:
            self.rs._flusher = None
        return report
