"""Single-query compatibility layer over the plan/execute engine (§2.4).

.. deprecated::
    ``QueryProcessor`` is the seed API's one-query-at-a-time shape, kept for
    back-compat only.  New code should use the session API — ``rs.snapshot()``
    + ``snap.execute([...])`` — which batches kernel launches and KVS round
    trips across queries and supports the full planner algebra
    (``Q.and_/or_/not_``, ``Q.count/exists/distinct``, ``snap.explain``).

The query path lives in :mod:`repro.core.plan` (logical IR + planner +
answer layer) and :mod:`repro.core.api` (the fetch layer): a
:class:`~repro.core.api.Snapshot` compiles a whole batch into one fused
bitmap-program launch and fetches every candidate chunk *and* chunk map in
ONE interleaved ``multiget`` round trip.  :class:`QueryProcessor` is
implemented as single-query batches on that engine, so each ``get_*`` costs
exactly one KVS round trip (the seed paid two: chunks, then maps).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .api import BatchResult, Q, Query, QueryResult, QueryStats, Snapshot
from .index import Projections
from .kvs import KVS
from .version_graph import VersionGraph

__all__ = ["QueryProcessor", "QueryStats", "Q", "Query", "QueryResult",
           "BatchResult", "Snapshot"]


class QueryProcessor:
    """One-query-at-a-time facade over :class:`Snapshot` (back-compat)."""

    def __init__(self, graph: VersionGraph, projections: Projections,
                 kvs: KVS) -> None:
        self.graph = graph
        self.proj = projections
        self.kvs = kvs
        self._snap = Snapshot(graph, projections, kvs)

    def _one(self, q: Query) -> QueryResult:
        return self._snap.execute([q])[0]

    def get_version(self, vid: int) -> Tuple[Dict[int, bytes], QueryStats]:
        r = self._one(Q.version(vid))
        return r.value, r.stats

    def get_range(self, vid: int, key_lo: int,
                  key_hi: int) -> Tuple[Dict[int, bytes], QueryStats]:
        r = self._one(Q.range(vid, key_lo, key_hi))
        return r.value, r.stats

    def get_record(self, vid: int, pk: int) -> Tuple[Optional[bytes], QueryStats]:
        r = self._one(Q.record(vid, pk))
        return r.value, r.stats

    def get_evolution(self, pk: int) -> Tuple[List[Tuple[int, bytes]], QueryStats]:
        r = self._one(Q.evolution(pk))
        return r.value, r.stats
