"""Query processing (§2.4): the four retrieval classes over chunked storage.

Every query follows the same shape: consult the lossy projection(s) → ONE
batched multiget of candidate chunks (+ their chunk maps) → use the exact
per-chunk maps to extract the relevant records.  Because the projections are
lossy, a fetched chunk may contain nothing relevant; stats record that.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .chunkstore import ChunkMap, StoredChunk
from .index import Projections
from .kvs import KVS
from .types import unpack_ck
from .version_graph import VersionGraph


@dataclass
class QueryStats:
    chunks_fetched: int = 0
    irrelevant_chunks: int = 0     # lossy-projection artifacts (§2.4)
    bytes_fetched: int = 0
    kvs_queries: int = 0
    records_returned: int = 0


class QueryProcessor:
    def __init__(self, graph: VersionGraph, projections: Projections,
                 kvs: KVS) -> None:
        self.graph = graph
        self.proj = projections
        self.kvs = kvs
        self._vidx = {v: i for i, v in enumerate(graph.versions)}

    # ------------------------------------------------------------- plumbing
    def _fetch(self, chunk_ids: np.ndarray,
               stats: QueryStats) -> List[Tuple[StoredChunk, ChunkMap]]:
        if len(chunk_ids) == 0:
            return []
        q0 = self.kvs.stats.n_queries
        b0 = self.kvs.stats.bytes_fetched
        blobs = self.kvs.multiget([f"chunk/{c}" for c in chunk_ids])
        maps = self.kvs.multiget([f"map/{c}" for c in chunk_ids])
        stats.chunks_fetched += len(chunk_ids)
        stats.kvs_queries += self.kvs.stats.n_queries - q0
        stats.bytes_fetched += self.kvs.stats.bytes_fetched - b0
        return [(StoredChunk.from_bytes(b), ChunkMap.from_bytes(m))
                for b, m in zip(blobs, maps)]

    # ------------------------------------------------------------ Q1: version
    def get_version(self, vid: int) -> Tuple[Dict[int, bytes], QueryStats]:
        stats = QueryStats()
        vidx = self._vidx[vid]
        out: Dict[int, bytes] = {}
        for chunk, cmap in self._fetch(self.proj.chunks_for_version(vid), stats):
            locs = cmap.records_in_version(vidx)
            if len(locs) == 0:
                stats.irrelevant_chunks += 1
                continue
            payloads = chunk.payloads()
            for li in locs:
                pk, _ = unpack_ck(int(cmap.cks[li]))
                out[pk] = payloads[int(li)]
        stats.records_returned = len(out)
        return out, stats

    # ----------------------------------------------------------- Q2: range
    def get_range(self, vid: int, key_lo: int,
                  key_hi: int) -> Tuple[Dict[int, bytes], QueryStats]:
        stats = QueryStats()
        vidx = self._vidx[vid]
        cand = self.proj.candidates_range(vid, key_lo, key_hi)
        out: Dict[int, bytes] = {}
        for chunk, cmap in self._fetch(cand, stats):
            locs = cmap.records_in_version(vidx)
            keys = (cmap.cks[locs] >> 32)
            sel = locs[(keys >= key_lo) & (keys <= key_hi)]
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            payloads = chunk.payloads()
            for li in sel:
                pk, _ = unpack_ck(int(cmap.cks[li]))
                out[pk] = payloads[int(li)]
        stats.records_returned = len(out)
        return out, stats

    # ---------------------------------------------------------- Q-point
    def get_record(self, vid: int, pk: int) -> Tuple[Optional[bytes], QueryStats]:
        stats = QueryStats()
        vidx = self._vidx[vid]
        cand = self.proj.candidates(vid, [pk])   # index-ANDing (bitmap kernel)
        result: Optional[bytes] = None
        for chunk, cmap in self._fetch(cand, stats):
            locs = cmap.records_in_version(vidx)
            keys = (cmap.cks[locs] >> 32)
            sel = locs[keys == pk]
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            result = chunk.payloads()[int(sel[0])]
            stats.records_returned = 1
        return result, stats

    # ------------------------------------------------------- Q3: evolution
    def get_evolution(self, pk: int) -> Tuple[List[Tuple[int, bytes]], QueryStats]:
        """All distinct records ever stored under ``pk`` (origin order)."""
        stats = QueryStats()
        out: List[Tuple[int, bytes]] = []
        for chunk, cmap in self._fetch(self.proj.chunks_for_key(pk), stats):
            keys = (cmap.cks >> 32)
            sel = np.flatnonzero(keys == pk)
            if len(sel) == 0:
                stats.irrelevant_chunks += 1
                continue
            payloads = chunk.payloads()
            for li in sel:
                _, origin = unpack_ck(int(cmap.cks[li]))
                out.append((origin, payloads[int(li)]))
        out.sort(key=lambda t: self._vidx.get(t[0], 1 << 30))
        stats.records_returned = len(out)
        return out, stats
