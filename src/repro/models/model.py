"""Model assembly: config → params / train_loss / prefill / decode.

Layers are stacked per *position-in-period* and scanned over groups
(`lax.scan`), so HLO size and compile time are depth-independent — a 61-layer
1T-param MoE compiles as one group body.  Heterogeneous patterns (Jamba's
attn/ssm 1:7 interleave with alternating dense/MoE FFN) unroll the period
inside the scanned group body.

Caches mirror the param structure: per position, stacked over groups, carried
through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import constrain
from .config import ModelConfig
from .layers import (ParamDef, attention, attn_defs, mlp, mlp_defs, moe,
                     moe_defs, moe_shard_map, rmsnorm, ssm_block, ssm_defs,
                     tree_abstract, tree_init)

Params = Dict[str, Any]


# ------------------------------------------------------------------ builders
def _block_defs(cfg: ModelConfig, plan, G: int) -> List[Dict[str, Any]]:
    """Param defs per position within the scan period, stacked over G groups."""
    out = []
    for mixer, ffn in plan:
        d: Dict[str, Any] = {}
        if mixer == "attn":
            d["attn"] = attn_defs(cfg, G)
        else:
            d["ssm"] = ssm_defs(cfg, G)
        if ffn == "dense":
            d["mlp"] = mlp_defs(cfg, G)
        elif ffn == "moe":
            d["moe"] = moe_defs(cfg, G)
        out.append(d)
    return out


def param_defs(cfg: ModelConfig) -> Params:
    D, Vp = cfg.d_model, cfg.padded_vocab
    defs: Params = {
        "embed": ParamDef((Vp, D), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamDef((D,), (None,), init="ones"),
        "blocks": _block_defs(cfg, cfg.layer_plan(), cfg.n_groups_scan),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, Vp), ("embed", "vocab"), scale=0.02)
    if cfg.family == "encdec":
        enc_plan = [("attn", "dense")] * 1
        defs["enc_blocks"] = _block_defs(cfg, enc_plan, cfg.n_encoder_layers)
        defs["enc_final_norm"] = ParamDef((D,), (None,), init="ones")
        defs["cross_blocks"] = [{"attn": attn_defs(cfg, cfg.n_groups_scan)}]
        # learned positions sized for the largest assigned decode shape (the
        # real whisper caps at 1500 frames / 448 tokens — stub, documented)
        defs["pos_embed"] = ParamDef((32768, D), (None, "embed"), scale=0.01)
    return defs


def abstract_params(cfg: ModelConfig, env=None):
    return tree_abstract(param_defs(cfg), cfg.jdtype, env)


def init_params(cfg: ModelConfig, key):
    return tree_init(param_defs(cfg), key, cfg.jdtype)


# ---------------------------------------------------------------- cache defs
def cache_defs(cfg: ModelConfig, batch: int, cache_len: int) -> List[Dict[str, Any]]:
    """Decode-cache structure mirroring the block structure (per position,
    stacked over groups)."""
    G = cfg.n_groups_scan
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    d_in = cfg.d_inner if cfg.ssm_state else 0
    N = cfg.ssm_groups * cfg.ssm_state
    out = []
    for mixer, _ in cfg.layer_plan():
        if mixer == "attn":
            out.append({"attn": {
                "k": ParamDef((G, batch, cache_len, Hkv, dh),
                              ("layers", "batch", "cache_seq", None, None)),
                "v": ParamDef((G, batch, cache_len, Hkv, dh),
                              ("layers", "batch", "cache_seq", None, None)),
            }})
        else:
            out.append({"ssm": {
                "state": ParamDef((G, batch, cfg.ssm_heads, cfg.ssm_head_dim, N),
                                  ("layers", "batch", "ssm_heads", None, None)),
                "conv": ParamDef((G, batch, cfg.conv_width - 1, d_in + 2 * N),
                                 ("layers", "batch", None, None)),
            }})
    return out


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int, env=None):
    def mk(name: str):
        def inner(d: ParamDef):
            # SSM recurrent state accumulates in f32; K/V + conv caches are
            # model dtype.  Keyed by name — NEVER by shape (head_dim can
            # coincide with ssm_state, e.g. both 128 in jamba).
            dtype = jnp.float32 if name == "state" else cfg.jdtype
            sharding = env.sharding_for(d.shape, d.axes) if env else None
            return jax.ShapeDtypeStruct(d.shape, dtype, sharding=sharding)
        return inner

    tree = []
    for c in cache_defs(cfg, batch, cache_len):
        tree.append({mix: {name: mk(name)(d) for name, d in sub.items()}
                     for mix, sub in c.items()})
    if cfg.family == "encdec":
        G = cfg.n_groups_scan
        enc_len = cross_len(cfg, cache_len)
        sh = ((G, batch, enc_len, cfg.n_kv_heads, cfg.head_dim),
              ("layers", "batch", None, None, None))
        mkx = lambda: jax.ShapeDtypeStruct(
            sh[0], cfg.jdtype,
            sharding=env.sharding_for(*sh) if env else None)
        tree.append({"cross": {"k": mkx(), "v": mkx()}})
    return tree


def zero_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, cache_len))


def cross_len(cfg: ModelConfig, cache_len: int) -> int:
    """Encoder context length for decode (whisper 30 s ≈ 1500 frames stub)."""
    return min(1500, cache_len)


# ------------------------------------------------------------------- forward
def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if cfg.remat == "dots_nb":
        # save weight-like dot outputs (MLP/projections) but recompute the
        # batched attention-score dots — the sweet spot once S² tensors
        # dominate traffic but weight-dot outputs fit in HBM
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _apply_block(cfg, pos_idx, bp, x, mode, cache, pos, aux):
    mixer_key = "attn" if "attn" in bp else "ssm"
    new_cache = {}
    if mixer_key == "attn":
        c = cache.get("attn") if cache else None
        x, nc = attention(bp["attn"], x, cfg, causal=True, mode=mode,
                          cache=c, pos=pos)
        if nc is not None:
            new_cache["attn"] = nc
    else:
        c = cache.get("ssm") if cache else None
        x, nc = ssm_block(bp["ssm"], x, cfg, mode=mode, cache=c)
        if nc is not None:
            new_cache["ssm"] = nc
    if "mlp" in bp:
        x = mlp(bp["mlp"], x, cfg)
    elif "moe" in bp:
        moe_fn = moe_shard_map if cfg.moe_impl == "shard_map" else moe
        x, a = moe_fn(bp["moe"], x, cfg)
        aux = aux + a
    if cfg.seq_parallel and mode in ("train", "prefill") and x.shape[1] > 1:
        x = constrain(x, "batch", "seq_sp", None)
    else:
        x = constrain(x, "batch", None, None)
    return x, new_cache, aux


def forward_blocks(cfg: ModelConfig, blocks, x, *, mode: str,
                   caches=None, pos=None):
    """Scan the stacked block groups.  Returns (x, new_caches, aux_loss).

    - train: no caches in or out.
    - prefill: no caches in; per-group caches emitted as scan outputs.
    - decode: caches in (scanned as xs) and out (scanned as ys).
    """
    plan = cfg.layer_plan()
    policy = _remat_policy(cfg)

    if caches is None:
        emit = mode == "prefill"

        def body(carry, bps):
            x, aux = carry
            new_cs = []
            for i in range(len(plan)):
                x, nc, aux = _apply_block(cfg, i, bps[i], x, mode, None, pos, aux)
                new_cs.append(nc)
            return (x, aux), (new_cs if emit else None)

        if policy is not None:
            body = jax.checkpoint(body, policy=policy)
        (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
        return x, (ys if emit else None), aux

    def body(carry, xs):
        x, aux = carry
        bps, cs = xs
        new_cs = []
        for i in range(len(plan)):
            x, nc, aux = _apply_block(cfg, i, bps[i], x, mode, cs[i], pos, aux)
            new_cs.append(nc)
        return (x, aux), new_cs

    if policy is not None:
        body = jax.checkpoint(body, policy=policy)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (blocks, caches))
    return x, new_caches, aux


def _logits(cfg: ModelConfig, params, x):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "batch", None, "vocab")
    return logits


def _mask_padded_vocab(cfg: ModelConfig, logits):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    v = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    return jnp.where(v < cfg.vocab_size, logits, -1e30)


# ------------------------------------------------------------------ encoders
def _encode(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over pre-embedded frames (conv frontend stub)."""
    x = frames + params["pos_embed"][: frames.shape[1]][None]

    def group(carry, bps):
        x, aux = carry
        x, _ = attention(bps[0]["attn"], x, cfg, causal=False, mode="train")
        x = mlp(bps[0]["mlp"], x, cfg)
        return (x, aux), None
    policy = _remat_policy(cfg)
    if policy is not None:
        group = jax.checkpoint(group, policy=policy)
    (x, _), _ = jax.lax.scan(group, (x, jnp.zeros((), jnp.float32)),
                             params["enc_blocks"])
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def _decoder_with_cross(cfg, params, x, enc_out, *, mode, caches=None, pos=None):
    """Decoder scan with interleaved cross-attention (enc-dec family)."""
    plan = cfg.layer_plan()
    use_cache = caches is not None
    self_caches = caches[:-1] if use_cache else None
    cross_cache = caches[-1]["cross"] if use_cache else None

    def group(carry, xs):
        x, aux = carry
        if use_cache:
            bps, cbp, cs, xc = xs
        else:
            bps, cbp = xs
            cs, xc = None, None
        new_cs = []
        for i in range(len(plan)):
            c = cs[i] if use_cache else None
            x, nc, aux = _apply_block(cfg, i, bps[i], x, mode, c, pos, aux)
            # cross-attention after self-attention
            if mode == "decode":
                x, _ = attention(cbp, x, cfg, mode="decode", cache=xc,
                                 pos=pos, is_cross=True)
            else:
                x, nxc = attention(cbp, x, cfg, mode=mode, kv_x=enc_out)
                if mode == "prefill":
                    nc = dict(nc)
                    nc["_cross"] = nxc
            new_cs.append(nc)
        return (x, aux), new_cs

    policy = _remat_policy(cfg)
    if policy is not None:
        group = jax.checkpoint(group, policy=policy)
    xs = (params["blocks"], params["cross_blocks"][0]["attn"])
    if use_cache:
        xs = xs + (self_caches, cross_cache)
        # scan over groups: cross_blocks stacked over G as well
    (x, aux), new_caches = jax.lax.scan(group, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


def _pad_attn_caches(caches, max_len: Optional[int]):
    """Pad attention K/V caches' sequence axis with decode headroom.

    Cache leaves are (G, B, S, Hkv, dh); cross caches keep encoder length."""
    if caches is None:
        return None
    out = []
    for c in caches:
        if "attn" in c:
            k, v = c["attn"]["k"], c["attn"]["v"]
            tgt = max_len if max_len is not None else 2 * k.shape[2]
            pad = max(0, tgt - k.shape[2])
            padw = ((0, 0),) * 2 + ((0, pad),) + ((0, 0),) * 2
            c = dict(c)
            c["attn"] = {"k": jnp.pad(k, padw), "v": jnp.pad(v, padw)}
        out.append(c)
    return out


# ------------------------------------------------------------------ the API
class Model:
    """Bundled callables for one architecture."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- train
    def train_logits(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, "batch", None, None)
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
        if cfg.family == "encdec":
            enc = _encode(cfg, params, batch["frames"].astype(x.dtype))
            x = x + params["pos_embed"][: x.shape[1]][None]
            x, _, aux = _decoder_with_cross(cfg, params, x, enc, mode="train")
        else:
            x, _, aux = forward_blocks(cfg, params["blocks"], x, mode="train")
        return _logits(cfg, params, x), aux

    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        logits, aux = self.train_logits(params, batch)
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            P = cfg.n_prefix_embeds
            logits = logits[:, P - 1:-1] if P > 0 else logits[:, :-1]
            targets = tokens
        else:
            logits, targets = logits[:, :-1], tokens[:, 1:]
        logits = _mask_padded_vocab(cfg, logits.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - gold)
        return ce + aux

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Full-sequence forward producing last-token logits + caches.

        ``max_len`` pads attention KV caches with headroom for subsequent
        decode steps (defaults to 2× the prompt length)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
        if cfg.family == "encdec":
            enc = _encode(cfg, params, batch["frames"].astype(x.dtype))
            x = x + params["pos_embed"][: x.shape[1]][None]
            x, caches, _ = _decoder_with_cross(cfg, params, x, enc, mode="prefill")
            # split the per-block "_cross" cache out into the trailing slot
            cross = {"cross": {"k": caches[0]["_cross"]["k"],
                               "v": caches[0]["_cross"]["v"]}} \
                if "_cross" in caches[0] else None
            self_caches = [{k: v for k, v in c.items() if k != "_cross"}
                           for c in caches]
            if cross is not None:
                self_caches.append(cross)
            caches = self_caches
        else:
            x, caches, _ = forward_blocks(cfg, params["blocks"], x,
                                          mode="prefill", caches=None,
                                          pos=None)
        caches = _pad_attn_caches(caches, max_len)
        logits = _logits(cfg, params, x[:, -1:])
        return _mask_padded_vocab(cfg, logits), caches

    # --------------------------------------------------------------- decode
    def decode_step(self, params, caches, tokens, pos):
        """One decode step: tokens (B, 1) at absolute position ``pos``."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, "batch", None, None)
        if cfg.family == "encdec":
            enc_out = None  # cross K/V precomputed in cache
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], pos, 1)[None]
            x, new_caches, _ = _decoder_with_cross(
                cfg, params, x, enc_out, mode="decode", caches=caches, pos=pos)
            new_caches = list(new_caches) + [caches[-1]]
        else:
            x, new_caches, _ = forward_blocks(cfg, params["blocks"], x,
                                              mode="decode", caches=caches,
                                              pos=pos)
        logits = _mask_padded_vocab(cfg, _logits(cfg, params, x))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
