"""Single config language for all assigned architectures."""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0                  # dense-FFN width (or per-expert width if MoE-only)
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_layer_period: int = 1      # MoE at layers i % period == offset
    moe_layer_offset: int = 0
    d_ff_expert: int = 0           # per-expert width (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    attn_layer_period: int = 0     # hybrid: attention at i % period == offset
    attn_layer_offset: int = 0
    # --- encoder-decoder ---
    n_encoder_layers: int = 0
    # --- modality stubs ---
    n_prefix_embeds: int = 0       # VLM patches / audio frames fed pre-embedded
    # --- misc ---
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    act: str = "silu_glu"          # silu_glu | gelu
    use_rope: bool = True
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    attn_impl: str = "dense"       # dense | blockwise
    attn_block_q: int = 1024
    attn_block_kv: int = 2048
    optimizer: str = "adamw"       # adamw | adafactor
    attn_batch_shard: bool = False  # reshard attention batch over (dp, tp):
    #                                 recovers the idle model axis when
    #                                 n_heads doesn't divide the TP width
    sharding_profile: str = "default"   # default (FSDP+TP) | dp_only
    attn_softmax_dtype: str = "f32"     # f32 | bf16 — dtype of the
    #                                     *materialized* S×S tensors (exp/probs
    #                                     stay f32 in-register either way)
    moe_impl: str = "gspmd"             # gspmd (auto) | shard_map (explicit
    #                                     local dispatch + output psum — no
    #                                     cross-device token exchange)
    seq_parallel: bool = False          # Megatron-SP: residual stream sharded
    #                                     over the model axis on the sequence
    #                                     dim between blocks (16× smaller
    #                                     stash/norm traffic; AR → AG+RS)
    notes: str = ""

    # ------------------------------------------------------------- derived
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def d_ff_e(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def layer_plan(self) -> List[Tuple[str, Optional[str]]]:
        """(mixer, ffn) per position within one scan period."""
        period = self.scan_period()
        plan = []
        for i in range(period):
            if self.family == "ssm":
                mixer = "ssm"
            elif self.family == "hybrid":
                mixer = ("attn" if self.attn_layer_period and
                         i % self.attn_layer_period == self.attn_layer_offset
                         else "ssm")
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn: Optional[str] = None
            elif self.n_experts and i % self.moe_layer_period == self.moe_layer_offset:
                ffn = "moe"
            else:
                ffn = "dense" if self.d_ff else None
            plan.append((mixer, ffn))
        return plan

    def scan_period(self) -> int:
        p = 1
        if self.family == "hybrid" and self.attn_layer_period:
            p = math.lcm(p, self.attn_layer_period)
        if self.n_experts:
            p = math.lcm(p, self.moe_layer_period)
        return p

    @property
    def n_groups_scan(self) -> int:
        period = self.scan_period()
        assert self.n_layers % period == 0, (self.n_layers, period)
        return self.n_layers // period

    # --------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Exact parameter count (excluding negligible norm scales)."""
        D, dh = self.d_model, self.head_dim
        total = self.padded_vocab * D * (1 if self.tie_embeddings else 2)
        enc_extra = 0
        for mixer, ffn in self.layer_plan() * self.n_groups_scan:
            if mixer == "attn":
                total += D * self.n_heads * dh * 2          # wq, wo
                total += D * self.n_kv_heads * dh * 2       # wk, wv
            else:
                d_in, H = self.d_inner, self.ssm_heads
                p_in = 2 * d_in + 2 * self.ssm_groups * self.ssm_state + H
                total += D * p_in + d_in * D
                total += self.conv_width * (d_in + 2 * self.ssm_groups * self.ssm_state)
            if ffn == "dense":
                total += 3 * D * self.d_ff
            elif ffn == "moe":
                total += D * self.n_experts
                total += 3 * D * self.d_ff_e * self.n_experts
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder already counted above
            enc_extra = self.n_encoder_layers * (
                D * self.n_heads * dh * 2 + D * self.n_kv_heads * dh * 2
                + (2 if self.act == "gelu" else 3) * D * self.d_ff)
            # decoder cross-attention
            enc_extra += self.n_layers * (D * self.n_heads * dh * 2
                                          + D * self.n_kv_heads * dh * 2)
        return total + enc_extra

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for m, f in self.layer_plan() if f == "moe") \
            * self.n_groups_scan
        expert_total = 3 * self.d_model * self.d_ff_e * self.n_experts * moe_layers
        expert_active = 3 * self.d_model * self.d_ff_e * self.moe_top_k * moe_layers
        return full - expert_total + expert_active

    def reduced(self, seed_layers: int = 0) -> "ModelConfig":
        """Smoke-test config: same family/pattern, tiny dims."""
        period = self.scan_period()
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=period * 2 if self.n_layers >= period * 2 else period,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(4, max(1, self.n_kv_heads)) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            d_ff_expert=32 if self.d_ff_expert else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            capacity_factor=8.0,    # drop-free: decode/prefill token counts
            #                         differ from train, so drops would make
            #                         smoke equivalence checks flaky

            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            attn_block_q=32,
            attn_block_kv=32,
            dtype="float32",
        )
