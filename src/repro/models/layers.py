"""Model building blocks, written against a uniform param-def system.

All parameters are declared as ``ParamDef(shape, logical_axes)`` trees so the
same builder serves three uses: abstract ShapeDtypeStructs (dry-run), sharded
NamedSharding specs (via sharding.rules), and concrete initialization (smoke
tests / the ~100M training example).

Blocks: RMSNorm, RoPE, GQA attention (dense, blockwise-flash, and decode
modes), SwiGLU/GELU MLP, top-k MoE with capacity-based scatter dispatch
(EP-shardable, no one-hot einsum so cost_analysis stays honest), and the
Mamba2 SSD mixer as a chunked ``lax.scan`` (VMEM-bounded working set).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import constrain
from .config import ModelConfig


# ---------------------------------------------------------------- param defs
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | ones | zeros | small_normal
    scale: float = 0.02

    def abstract(self, dtype, env=None) -> jax.ShapeDtypeStruct:
        sharding = env.sharding_for(self.shape, self.axes) if env else None
        return jax.ShapeDtypeStruct(self.shape, dtype, sharding=sharding)

    def initialize(self, key, dtype) -> jax.Array:
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        scale = self.scale
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(dtype)


def tree_abstract(defs, dtype, env=None):
    return jax.tree.map(lambda d: d.abstract(dtype, env), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def tree_init(defs, key, dtype):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [d.initialize(k, dtype)
                                        for d, k in zip(leaves, keys)])


def tree_pspecs(defs, env):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda d: env.sharding_for(d.shape, d.axes), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------- norms
def _rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with a memory-lean hand-written backward.

    JAX's autodiff of the straightforward formulation materializes several
    f32 (B,S,D) intermediates at fusion boundaries in the backward pass —
    measured at ~640 GB/tensor on the kimi-k2 train cell (§Perf kimi it3).
    The custom VJP keeps every (B,S,D) boundary tensor in the input dtype,
    with only (B,S,1) f32 row statistics."""
    return _rmsnorm_ref(x, scale, eps)


def _rmsnorm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (xf * rstd).astype(x.dtype) * scale
    return y, (x, scale, rstd)


def _rmsnorm_bwd(eps, res, g):
    x, scale, rstd = res
    xn = (x.astype(jnp.float32) * rstd).astype(x.dtype)        # normalized, bf16
    gs = g * scale                                             # bf16
    dscale = jnp.sum((g.astype(jnp.float32)
                      * xn.astype(jnp.float32)).reshape(-1, x.shape[-1]),
                     axis=0).astype(scale.dtype)
    c = jnp.mean((gs.astype(jnp.float32) * xn.astype(jnp.float32)),
                 axis=-1, keepdims=True)                       # (B,S,1) f32
    dx = ((gs.astype(jnp.float32) - xn.astype(jnp.float32) * c)
          * rstd).astype(x.dtype)
    return dx, dscale


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


# ---------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))          # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def attn_defs(cfg: ModelConfig, L: int) -> Dict[str, ParamDef]:
    D, Hq, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = {
        "norm": ParamDef((L, D), ("layers", None), init="ones"),
        "wq": ParamDef((L, D, Hq, dh), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamDef((L, D, Hkv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ParamDef((L, D, Hkv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ParamDef((L, Hq, dh, D), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((L, Hq, dh), ("layers", "heads", "head_dim"), init="zeros")
        d["bk"] = ParamDef((L, Hkv, dh), ("layers", "kv_heads", "head_dim"), init="zeros")
        d["bv"] = ParamDef((L, Hkv, dh), ("layers", "kv_heads", "head_dim"), init="zeros")
    return d


def _split_heads_q(q, Hkv):
    # (B, S, Hq, dh) -> (B, S, Hkv, G, dh)
    B, S, Hq, dh = q.shape
    return q.reshape(B, S, Hkv, Hq // Hkv, dh)


def _sm_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.attn_softmax_dtype == "bf16" else jnp.float32


def _dense_attention(q, k, v, *, causal: bool, q_offset, kv_len_mask=None,
                     softmax_dtype=jnp.float32):
    """q: (B,Sq,Hkv,G,dh); k/v: (B,Skv,Hkv,dh). Returns (B,Sq,Hkv,G,dh).

    ``softmax_dtype`` controls the dtype of the *materialized* S×S tensors
    (logits / exp / probs); max/sum reductions always accumulate in f32.
    """
    dh = q.shape[-1]
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(softmax_dtype)
    logits = logits * softmax_dtype(1.0 / math.sqrt(dh))
    Sq, Skv = q.shape[1], k.shape[1]
    neg = softmax_dtype(-1e30)
    if causal:
        qi = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 0) + q_offset
        ki = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 1)
        logits = jnp.where(qi >= ki, logits, neg)
    if kv_len_mask is not None:                       # (B, Skv) bool
        logits = jnp.where(kv_len_mask[:, None, None, None, :], logits, neg)
    # NB: an explicit max/exp/div decomposition and bf16-materialized
    # softmax were both tried and REFUTED on the traffic model (XLA inserts
    # extra convert copies in the backward pass) — see EXPERIMENTS.md §Perf.
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _blockwise_attention(q, k, v, *, causal: bool, q_block: int, kv_block: int):
    """Flash-style online-softmax attention in pure JAX: scan over q blocks
    (outer) and kv blocks (inner), O(Sq·dh + qb·kb) live memory."""
    B, Sq, Hkv, G, dh = q.shape
    Skv = k.shape[1]
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    assert Sq % qb == 0 and Skv % kb == 0
    nq, nk = Sq // qb, Skv // kb
    scale = 1.0 / math.sqrt(dh)

    qr = jnp.moveaxis(q.reshape(B, nq, qb, Hkv, G, dh), 1, 0)      # (nq,B,qb,...)
    kr = jnp.moveaxis(k.reshape(B, nk, kb, Hkv, dh), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kb, Hkv, dh), 1, 0)

    def q_step(_, qi_blk):
        qi, q_i = qi_blk                                            # index, block

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_j, v_j = kj_blk
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                qidx = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
                kidx = kj * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
                s = jnp.where(qidx >= kidx, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32),
                jnp.zeros((B, Hkv, G, qb), jnp.float32),
                jnp.zeros((B, Hkv, G, qb, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (jnp.arange(nk), kr, vr))
        out = (acc / l[..., None]).astype(q.dtype)                  # (B,Hkv,G,qb,dh)
        return None, jnp.moveaxis(out, 3, 1)                        # (B,qb,Hkv,G,dh)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))    # (nq,B,qb,...)
    return jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, Hkv, G, dh)


def attention(p, x: jax.Array, cfg: ModelConfig, *, causal: bool = True,
              mode: str = "train", cache: Optional[dict] = None,
              pos=None, kv_x: Optional[jax.Array] = None,
              is_cross: bool = False,
              positions: Optional[jax.Array] = None):
    """Pre-norm GQA attention block.  Returns (residual_out, new_cache).

    modes: "train"/"prefill" — full-sequence; prefill additionally emits a KV
    cache.  "decode" — S==1 step against ``cache`` at position ``pos``.
    ``kv_x``/``is_cross`` switch to cross-attention (keys/values from encoder
    states; in decode the cache holds precomputed cross K/V, never updated).
    """
    B, S, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    cross = is_cross or kv_x is not None
    if cross and cache is not None and mode == "decode":
        k, v = cache["k"], cache["v"]          # precomputed cross K/V
        new_cache = cache
    else:
        src = kv_x if cross else h
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        new_cache = None

    if positions is None:
        positions = (jnp.arange(S, dtype=jnp.int32) if mode != "decode"
                     else jnp.asarray(pos, jnp.int32)[None].reshape(1,))
    if cfg.use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    qg = _split_heads_q(q, Hkv)

    reshard_batch = (cfg.attn_batch_shard and mode in ("train", "prefill")
                     and not cross)
    if reshard_batch:
        qg = constrain(qg, "attn_batch", None, None, None, None)
        k = constrain(k, "attn_batch", None, None, None)
        v = constrain(v, "attn_batch", None, None, None)

    if mode == "decode" and not cross:
        # write into the cache, attend over valid prefix
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                               (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                               (0, pos, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        Skv = k_cache.shape[1]
        valid = (jax.lax.broadcasted_iota(jnp.int32, (1, Skv), 1) <= pos)
        valid = jnp.broadcast_to(valid, (B, Skv))
        out = _dense_attention(qg, k_cache, v_cache, causal=False,
                               q_offset=0, kv_len_mask=valid,
                               softmax_dtype=_sm_dtype(cfg))
    elif mode == "decode" and cross:
        out = _dense_attention(qg, k, v, causal=False, q_offset=0,
                               softmax_dtype=_sm_dtype(cfg))
    elif cfg.attn_impl == "blockwise" and mode in ("train", "prefill") and not cross:
        out = _blockwise_attention(qg, k, v, causal=causal,
                                   q_block=cfg.attn_block_q,
                                   kv_block=cfg.attn_block_kv)
    else:
        out = _dense_attention(qg, k, v, causal=causal and not cross,
                               q_offset=0, softmax_dtype=_sm_dtype(cfg))

    if mode == "prefill":
        new_cache = {"k": k, "v": v}   # cross prefill caches encoder K/V too
    if reshard_batch:
        out = constrain(out, "attn_batch", None, None, None, None)
    out = out.reshape(B, S, Hq, dh)
    out = constrain(out, "batch", None, "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + y, new_cache


# ----------------------------------------------------------------------- MLP
def mlp_defs(cfg: ModelConfig, L: int) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    d = {"norm": ParamDef((L, D), ("layers", None), init="ones"),
         "wu": ParamDef((L, D, F), ("layers", "embed", "mlp")),
         "wd": ParamDef((L, F, D), ("layers", "mlp", "embed"))}
    if cfg.act == "silu_glu":
        d["wg"] = ParamDef((L, D, F), ("layers", "embed", "mlp"))
    return d


def mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, p["wu"])
    if cfg.act == "silu_glu":
        up = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, p["wg"])) * up
    else:
        up = jax.nn.gelu(up)
    y = jnp.einsum("bsf,fd->bsd", up, p["wd"])
    return x + y


# ----------------------------------------------------------------------- MoE
def moe_defs(cfg: ModelConfig, L: int) -> Dict[str, ParamDef]:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_e
    return {
        "norm": ParamDef((L, D), ("layers", None), init="ones"),
        "router": ParamDef((L, D, E), ("layers", "embed", "experts")),
        "wg": ParamDef((L, E, D, Fe), ("layers", "experts", "embed", "expert_mlp")),
        "wu": ParamDef((L, E, D, Fe), ("layers", "experts", "embed", "expert_mlp")),
        "wd": ParamDef((L, E, Fe, D), ("layers", "experts", "expert_mlp", "embed")),
    }


def moe(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with capacity-bounded scatter dispatch.

    Dispatch/combine are gathers/scatters (zero matmul FLOPs — keeps the
    roofline's MODEL_FLOPS/HLO_FLOPs ratio honest).  Position-in-expert is
    computed with a sort (O(Tk log Tk)) instead of the (T, E) one-hot cumsum
    (O(T·E) memory — prohibitive at kimi-k2 scale).  Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    h = rmsnorm(x, p["norm"], cfg.norm_eps).reshape(T, D)

    logits = jnp.einsum("td,de->te", h, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- position within expert via sort --------------------------------
    cap = int(math.ceil(T * K / E * cfg.capacity_factor))
    cap = max(cap, K)
    flat_e = eid.reshape(-1)                                # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * K) - first[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted).reshape(T, K)
    keep = pos < cap                                        # capacity drop
    pos_c = jnp.where(keep, pos, cap)                       # overflow slot

    # ---- dispatch: (E, cap+1, D) scatter ---------------------------------
    buf = jnp.zeros((E, cap + 1, D), x.dtype)
    xk = jnp.broadcast_to(h[:, None, :], (T, K, D)) * keep[..., None].astype(x.dtype)
    buf = buf.at[eid.reshape(-1), pos_c.reshape(-1)].add(
        xk.reshape(T * K, D))
    buf = buf[:, :cap]
    buf = constrain(buf, "experts", "capacity", None)

    # ---- expert computation ---------------------------------------------
    up = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * up
    out_buf = jnp.einsum("ecf,efd->ecd", up, p["wd"])
    out_buf = constrain(out_buf, "experts", "capacity", None)

    # ---- combine: gather back --------------------------------------------
    got = out_buf[eid.reshape(-1), jnp.minimum(pos_c, cap - 1).reshape(-1)]
    got = got.reshape(T, K, D) * (gate * keep).astype(x.dtype)[..., None]
    y = got.sum(axis=1).reshape(B, S, D)

    # ---- load-balance aux loss (Switch-style) -----------------------------
    frac_tokens = jnp.zeros(E, jnp.float32).at[eid.reshape(-1)].add(
        1.0 / (T * K))
    mean_prob = probs.mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * mean_prob)
    return x + y, aux


def moe_shard_map(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with *zero token exchange* (beyond-paper §Perf).

    Observation: activations are batch-sharded over the data axes and
    replicated over the model axis, while experts are sharded over the model
    axis — so every (data, model) device already holds all of its data
    shard's tokens AND its expert subset.  Dispatch/combine are therefore
    purely local; the only communication is (a) the FSDP all-gather of the
    expert weights' embed shards (identical to the dense path) and (b) one
    psum of the combined output over the model axis.  This replaces GSPMD's
    scatter→all-reduce dispatch lowering (≈ TBs of ring traffic per step on
    the MoE cells; see EXPERIMENTS.md §Perf).

    Capacity semantics: per (data-shard, expert) — the per-device capacity
    real EP systems use — vs the gspmd path's global per-expert capacity.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..sharding.rules import current_env
    env = current_env()
    if env is None:
        return moe(p, x, cfg)          # no mesh (unit tests): gspmd path
    mesh = env.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"
    E, K = cfg.n_experts, cfg.moe_top_k
    if tp not in mesh.axis_names:
        return moe(p, x, cfg)
    tp_size = mesh.shape[tp]
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    if E % tp_size or x.shape[0] % dp_size or x.shape[1] == 1:
        # indivisible experts/batch (e.g. batch-1 long-context decode), or
        # single-token decode (dispatch is trivial; the local-dispatch
        # machinery measurably regresses it — §Perf): gspmd fallback
        return moe(p, x, cfg)
    E_l = E // tp_size

    def local_moe(norm, router, wg, wu, wd, x_l):
        # gather FSDP weight shards (backward: psum_scatter — ZeRO-3)
        for ax in dp:
            router = jax.lax.all_gather(router, ax, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
        B_l, S, D = x_l.shape
        T = B_l * S
        h = rmsnorm(x_l, norm, cfg.norm_eps).reshape(T, D)
        logits = jnp.einsum("td,de->te", h, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, K)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        cap = max(K, int(math.ceil(T * K / E * cfg.capacity_factor)))
        flat_e = eid.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        first = jnp.searchsorted(flat_e[order], jnp.arange(E), side="left")
        pos_sorted = jnp.arange(T * K) - first[flat_e[order]]
        pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted).reshape(T, K)
        keep = pos < cap

        m_idx = jax.lax.axis_index(tp)
        local_e = eid - m_idx * E_l
        mine = (local_e >= 0) & (local_e < E_l) & keep
        e_c = jnp.where(mine, local_e, 0)
        pos_c = jnp.where(mine, pos, cap)

        # gather-based dispatch: scatter only the int32 slot->token map, then
        # gather token rows — avoids materializing the (T, K, D) broadcast
        # (≈6× dispatch traffic; see §Perf granite it3)
        slot = (e_c * (cap + 1) + pos_c).reshape(-1)          # (T*K,)
        tok_of = jnp.full(E_l * (cap + 1), -1, jnp.int32) \
            .at[slot].set(jnp.arange(T * K, dtype=jnp.int32) // K)
        filled = (tok_of >= 0)[:, None].astype(x_l.dtype)
        buf = (h[jnp.maximum(tok_of, 0)] * filled) \
            .reshape(E_l, cap + 1, D)[:, :cap]

        up = jnp.einsum("ecd,edf->ecf", buf, wu)
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * up
        out_buf = jnp.einsum("ecf,efd->ecd", up, wd)

        got = out_buf[e_c.reshape(-1), jnp.minimum(pos_c, cap - 1).reshape(-1)]
        got = got.reshape(T, K, D) * (gate * mine).astype(x_l.dtype)[..., None]
        y = jax.lax.psum(got.sum(axis=1), tp).reshape(B_l, S, D)

        frac = jnp.zeros(E, jnp.float32).at[flat_e].add(1.0 / (T * K))
        aux = cfg.router_aux_coef * E * jnp.sum(frac * probs.mean(0))
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(None),                       # norm: replicated
                  P(dp_spec, None),              # router: (D/dp, E)
                  P(tp, dp_spec, None),          # wg: (E/tp, D/dp, F)
                  P(tp, dp_spec, None),          # wu
                  P(tp, None, dp_spec),          # wd: (E/tp, F, D/dp)
                  P(dp_spec, None, None)),       # x: (B/dp, S, D)
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False,
    )(p["norm"], p["router"], p["wg"], p["wu"], p["wd"], x)
    return x + y, aux


# ------------------------------------------------------------------ SSD/SSM
def ssm_defs(cfg: ModelConfig, L: int) -> Dict[str, ParamDef]:
    D = cfg.d_model
    d_in, H = cfg.d_inner, cfg.ssm_heads
    GN = cfg.ssm_groups * cfg.ssm_state
    return {
        "norm": ParamDef((L, D), ("layers", None), init="ones"),
        "in_z": ParamDef((L, D, d_in), ("layers", "embed", "ssm_proj")),
        "in_x": ParamDef((L, D, d_in), ("layers", "embed", "ssm_proj")),
        "in_B": ParamDef((L, D, GN), ("layers", "embed", None)),
        "in_C": ParamDef((L, D, GN), ("layers", "embed", None)),
        "in_dt": ParamDef((L, D, H), ("layers", "embed", "ssm_heads")),
        "conv_x": ParamDef((L, cfg.conv_width, d_in), ("layers", None, "ssm_proj"),
                           init="small_normal", scale=0.1),
        "conv_B": ParamDef((L, cfg.conv_width, GN), ("layers", None, None),
                           init="small_normal", scale=0.1),
        "conv_C": ParamDef((L, cfg.conv_width, GN), ("layers", None, None),
                           init="small_normal", scale=0.1),
        "A_log": ParamDef((L, H), ("layers", "ssm_heads"), init="zeros"),
        "Dskip": ParamDef((L, H), ("layers", "ssm_heads"), init="ones"),
        "dt_bias": ParamDef((L, H), ("layers", "ssm_heads"), init="zeros"),
        "gate_norm": ParamDef((L, d_in), ("layers", "ssm_proj"), init="ones"),
        "out": ParamDef((L, d_in, D), ("layers", "ssm_proj", "embed")),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (W, C) depthwise causal conv."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return jax.lax.conv_general_dilated(
        xp, w[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])


def _ssd_chunk_scan(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD (state-space duality) scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm/Cm: (B,S,N) (single group broadcast over heads).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, Q, *a.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(to_chunks, (x, dt, Bm, Cm))      # (nc, B, Q, ...)

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(state, inp):
        x_c, dt_c, B_c, C_c = inp                           # (B,Q,H,P) etc.
        dA = dt_c * A                                       # (B,Q,H) ≤ 0
        cs = jnp.cumsum(dA, axis=1)                         # inclusive
        # inter-chunk: contribution of carried state
        y_off = jnp.einsum("bqn,bhpn->bqhp", C_c,
                           state.astype(x_c.dtype)) * jnp.exp(cs)[..., None].astype(x_c.dtype)
        # intra-chunk (masked decay kernel)
        att = jnp.einsum("bqn,bkn->bqk", C_c, B_c)          # (B,Q,Q)
        Ld = cs[:, :, None, :] - cs[:, None, :, :]          # (B,Q,K,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        w = att[..., None] * jnp.where(tri[None, :, :, None],
                                       jnp.exp(Ld), 0.0).astype(x_c.dtype)
        w = w * dt_c.astype(x_c.dtype)[:, None, :, :]
        y_in = jnp.einsum("bqkh,bkhp->bqhp", w, x_c)
        # state update
        decay_end = jnp.exp(cs[:, -1:, :] - cs)             # (B,Q,H)
        contrib = jnp.einsum("bqn,bqh,bqhp->bhpn", B_c,
                             (dt_c * decay_end), x_c).astype(jnp.float32)
        state = state * jnp.exp(cs[:, -1, :]).astype(jnp.float32)[:, :, None, None] \
            + contrib
        return state, y_in + y_off

    final, yc = jax.lax.scan(step, init_state, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, P)
    return y, final


def ssm_block(p, x: jax.Array, cfg: ModelConfig, *, mode: str = "train",
              cache: Optional[dict] = None):
    """Mamba2 (SSD) mixer.  Returns (residual_out, new_cache).

    cache (decode): {"state": (B,H,P,N) f32, "conv": (B,W-1,C)}.
    """
    B, S, D = x.shape
    d_in, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    N = cfg.ssm_groups * cfg.ssm_state
    W = cfg.conv_width
    h = rmsnorm(x, p["norm"], cfg.norm_eps)

    z = jnp.einsum("bsd,de->bse", h, p["in_z"])
    xs = jnp.einsum("bsd,de->bse", h, p["in_x"])
    Bm = jnp.einsum("bsd,dn->bsn", h, p["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", h, p["in_dt"])

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if mode == "decode":
        conv_cat = jnp.concatenate([xs, Bm, Cm], axis=-1)   # (B,1,C)
        hist = jnp.concatenate([cache["conv"], conv_cat], axis=1)  # (B,W,C)
        wcat = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
        conv_out = jnp.einsum("bwc,wc->bc", hist, wcat)[:, None, :]
        conv_out = jax.nn.silu(conv_out)
        xs2 = conv_out[..., :d_in]
        Bm2 = conv_out[..., d_in:d_in + N]
        Cm2 = conv_out[..., d_in + N:]
        xh = xs2.reshape(B, H, P)
        state = cache["state"]
        dA = jnp.exp(dt[:, 0] * A)                          # (B,H)
        contrib = jnp.einsum("bn,bh,bhp->bhpn", Bm2[:, 0], dt[:, 0], xh)
        state = state * dA[..., None, None] + contrib.astype(jnp.float32)
        y = jnp.einsum("bn,bhpn->bhp", Cm2[:, 0], state.astype(x.dtype))
        y = (y + p["Dskip"].astype(x.dtype)[None, :, None] * xh).astype(x.dtype)
        y = y.reshape(B, 1, d_in)
        new_cache = {"state": state, "conv": hist[:, 1:]}
    else:
        raw = jnp.concatenate([xs, Bm, Cm], axis=-1)        # pre-conv inputs
        xs = jax.nn.silu(_causal_depthwise_conv(xs, p["conv_x"]))
        Bm = jax.nn.silu(_causal_depthwise_conv(Bm, p["conv_B"]))
        Cm = jax.nn.silu(_causal_depthwise_conv(Cm, p["conv_C"]))
        xh = xs.reshape(B, S, H, P)
        y, final_state = _ssd_chunk_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        y = y + p["Dskip"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(B, S, d_in)
        new_cache = None
        if mode == "prefill":
            new_cache = {"state": final_state, "conv": raw[:, -(W - 1):]}

    y = y * jax.nn.silu(z[:, :y.shape[1]])
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return x + out, new_cache
