"""Async ingest: BackgroundFlusher watermarks, cross-session batching,
barrier/drain idempotence, failure replay, snapshot modes, and the
no-op conventions (empty drain, closed-session flush, double close)."""
import pytest

from repro.core import (CachingKVS, FaultInjectingKVS, InMemoryKVS, KVSStats,
                        Q, RStore, RStoreConfig, RetryPolicy, ShardedKVS,
                        keep_last)
from repro.core.flusher import BackgroundFlusher, DrainReport
from repro.core.replica import BackendUnavailable, TransientBackendError
from repro.serve.ingest_gateway import IngestGateway


def _payload(i, n=48):
    return bytes([i % 251]) * n


def _store(n_shards=0, **cfg_kw):
    cfg_kw.setdefault("capacity", 512)
    cfg_kw.setdefault("batch_size", 10**9)
    kvs = (InMemoryKVS() if n_shards == 0 else
           ShardedKVS([InMemoryKVS() for _ in range(n_shards)]))
    return RStore(RStoreConfig(**cfg_kw), kvs=kvs), kvs


def _boot_root(rs, n=8):
    """Stage a root through a short-lived session (no drain)."""
    with rs.writer() as w:
        return w.init_root({pk: _payload(pk) for pk in range(n)})


# ------------------------------------------------------- watermark triggers
def test_version_watermark_triggers_drain():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=4)
    root = _boot_root(rs)
    w = rs.writer()
    v = root
    for i in range(2):
        v = w.commit([v], adds={100 + i: _payload(i)})
    assert kvs.stats.n_put_queries == 0          # 3 staged < 4
    v = w.commit([v], adds={200: _payload(7)})   # 4th: watermark fires
    assert kvs.stats.n_flush_batches == 1
    assert kvs.stats.n_put_queries >= 1
    assert rs.flusher.staleness_lag == 0
    w.close()


def test_byte_watermark_triggers_drain():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9, max_staged_bytes=600)
    root = _boot_root(rs)                        # 8 * 48 = 384 bytes staged
    assert kvs.stats.n_put_queries == 0
    w = rs.writer()
    w.commit([root], adds={100: _payload(1, 300)})  # 684 >= 600: drain
    assert kvs.stats.n_flush_batches == 1
    assert rs.flusher.staged_bytes == 0
    w.close()


def test_age_watermark_triggers_drain():
    rs, kvs = _store()
    fl = rs.attach_flusher(max_staged_versions=10**9, max_staged_age=5)
    _boot_root(rs)
    assert kvs.stats.n_flush_batches == 0
    fl.tick(2)                                   # oldest age < 5: no drain
    assert kvs.stats.n_flush_batches == 0
    fl.tick(5)
    assert kvs.stats.n_flush_batches == 1
    assert fl.staleness_lag == 0


def test_no_drain_below_watermarks():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=100, max_staged_bytes=1 << 30)
    root = _boot_root(rs)
    w = rs.writer()
    v = root
    for i in range(10):
        v = w.commit([v], adds={100 + i: _payload(i)})
    w.close()
    assert kvs.stats.n_put_queries == 0
    assert kvs.stats.n_queries == 0
    assert rs.flusher.staleness_lag == 11        # root + 10 commits


# -------------------------------------------------- cross-session batching
def test_concurrent_sessions_allowed_in_async_mode():
    rs, _ = _store()
    rs.attach_flusher()
    ws = [rs.writer() for _ in range(4)]
    assert all(not w._closed for w in ws)
    for w in ws:
        w.close()


def test_sync_mode_still_one_writer():
    rs, _ = _store()
    w = rs.writer()
    with pytest.raises(RuntimeError, match="already open"):
        rs.writer()
    w.close()


def test_cross_session_drain_round_trips():
    """K sessions' staged versions drain in <= S write round trips on S
    shards — one group commit for everyone, not one per session."""
    n_shards, n_sessions, n_commits = 4, 6, 5
    rs, kvs = _store(n_shards=n_shards)
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs, n=16)
    sessions = [rs.writer() for _ in range(n_sessions)]
    heads = [root] * n_sessions
    for step in range(n_commits):
        for j, w in enumerate(sessions):
            heads[j] = w.commit([heads[j]],
                                adds={1000 * (j + 1) + step: _payload(j)})
    assert kvs.stats.n_put_queries == 0          # staging is free
    rep = rs.barrier()
    assert rep.n_versions == 1 + n_sessions * n_commits
    assert rep.write_round_trips <= n_shards
    for w in sessions:
        w.close()

    # per-session sync flush baseline pays >= one group commit per session
    rs0, kvs0 = _store(n_shards=n_shards)
    root0 = _boot_root(rs0, n=16)     # flush_on_close=True default -> flush
    rs0.flush()
    base = kvs0.stats.n_put_queries
    heads0 = [root0] * n_sessions
    for j in range(n_sessions):
        with rs0.writer() as w:
            for step in range(n_commits):
                heads0[j] = w.commit([heads0[j]],
                                     adds={1000 * (j + 1) + step: _payload(j)})
    sync_rts = kvs0.stats.n_put_queries - base
    assert sync_rts >= n_sessions                # one+ round trip per session
    assert rep.write_round_trips < sync_rts

    # byte-identical content either way
    for v, v0 in zip(heads, heads0):
        assert rs.get_version(v)[0] == rs0.get_version(v0)[0]


def test_facade_commit_stages_through_flusher():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    v = rs.commit([root], adds={100: _payload(1)})   # facade wrapper
    assert kvs.stats.n_put_queries == 0
    assert rs.flusher.staleness_lag == 2
    assert rs.get_version(v)[0][100] == _payload(1)  # fresh snapshot drains


# ------------------------------------------------ barrier/drain idempotence
def test_barrier_empty_is_free():
    rs, kvs = _store()
    rs.attach_flusher()
    _boot_root(rs)
    rs.barrier()
    before = kvs.stats.snapshot()
    rep = rs.barrier()                           # nothing staged
    assert rep == DrainReport(step=rep.step)
    assert rep.write_round_trips == 0
    assert kvs.stats.snapshot() == before        # zero stats noise
    rep2 = rs.flusher.drain()
    assert rep2.n_versions == 0 and kvs.stats.snapshot() == before


def test_barrier_drains_everything_once():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    w = rs.writer()
    w.commit([root], adds={100: _payload(1)})
    r1 = rs.barrier()
    r2 = rs.barrier()
    assert r1.n_versions == 2 and r2.n_versions == 0
    assert kvs.stats.n_flush_batches == 1
    w.close()


def test_sync_barrier_flushes_pending_and_empty_is_noop():
    rs, kvs = _store(batch_size=10**9)
    rs.init_root({pk: _payload(pk) for pk in range(4)})
    assert rs.pending and kvs.stats.n_put_queries == 0
    rs.barrier()
    assert not rs.pending and kvs.stats.n_put_queries >= 1
    before = kvs.stats.snapshot()
    assert rs.barrier() is None
    assert kvs.stats.snapshot() == before


def test_virtual_clock_advances_on_events():
    rs, _ = _store()
    fl = rs.attach_flusher()
    s0 = fl.step
    _boot_root(rs)                  # stage + session close tick
    assert fl.step > s0
    s1 = fl.step
    fl.tick(3)
    assert fl.step == s1 + 3
    rs.barrier()
    assert fl.step == s1 + 4


# ------------------------------------------------------ flush-failure replay
def test_flush_failure_keeps_staged_versions():
    fkvs = FaultInjectingKVS(InMemoryKVS())
    rs = RStore(RStoreConfig(capacity=512, batch_size=10**9), kvs=fkvs)
    fl = rs.attach_flusher(max_staged_versions=10**9,
                           retry=RetryPolicy(max_retries=1))
    w = rs.writer()
    root = w.init_root({pk: _payload(pk) for pk in range(6)})
    v1 = w.commit([root], adds={100: _payload(1)})
    fkvs.schedule_faults(["transient", "transient"])  # exhausts retries
    with pytest.raises(TransientBackendError):
        rs.barrier()
    assert fl.has_unacked_writes
    assert fl.staleness_lag == 2                 # staged versions survive
    rep = rs.barrier()                           # backend healthy again
    assert rep.replayed and rep.n_versions == 2
    assert not fl.has_unacked_writes
    w.close()
    assert rs.get_version(v1)[0][100] == _payload(1)


def test_timeout_mid_drain_replay_is_idempotent():
    """BackendTimeout = applied but ack lost: the retry re-puts the same
    batch; results must be byte-identical to a fault-free oracle."""
    fkvs = FaultInjectingKVS(InMemoryKVS())
    rs = RStore(RStoreConfig(capacity=512, batch_size=10**9), kvs=fkvs)
    rs.attach_flusher(max_staged_versions=10**9)
    rs0, _ = _store()                            # fault-free oracle
    rs0.attach_flusher(max_staged_versions=10**9)
    for store in (rs, rs0):
        w = store.writer()
        r = w.init_root({pk: _payload(pk) for pk in range(6)})
        w.commit([r], adds={100: _payload(1)}, dels=[2])
        w.close()
    fkvs.schedule_faults(["timeout"])
    rs.barrier()
    rs0.barrier()
    assert fkvs.stats.n_retries == 1
    assert rs.get_version(1)[0] == rs0.get_version(1)[0]
    assert dict(fkvs.inner.scan()) == dict(rs0.kvs.scan())


def test_failed_drain_then_new_stages_merge_into_one_replay():
    fkvs = FaultInjectingKVS(InMemoryKVS())
    rs = RStore(RStoreConfig(capacity=512, batch_size=10**9), kvs=fkvs)
    fl = rs.attach_flusher(max_staged_versions=10**9,
                           retry=RetryPolicy(max_retries=0))
    w = rs.writer()
    root = w.init_root({pk: _payload(pk) for pk in range(6)})
    fkvs.schedule_faults(["transient"])
    with pytest.raises(TransientBackendError):
        rs.barrier()
    v1 = w.commit([root], adds={100: _payload(1)})
    p0 = fkvs.stats.n_put_queries
    rep = rs.barrier()                           # old replay + new batch
    assert rep.replayed and rep.n_versions == 2
    assert fkvs.stats.n_put_queries - p0 == 1    # still ONE multiput
    assert kvs_retained_versions_ok(rs, [root, v1])
    assert fl.staleness_lag == 0
    w.close()


def kvs_retained_versions_ok(rs, vids):
    for v in vids:
        got = rs.snapshot().execute([Q.version(v)])[0].value
        m = rs.graph.members(v)
        keys = rs.graph.store.keys()
        want = {int(keys[r]): rs.graph.store.payload(int(r)) for r in m}
        if got != want:
            return False
    return True


def test_failed_drain_blocks_pinned_snapshot():
    fkvs = FaultInjectingKVS(InMemoryKVS())
    rs = RStore(RStoreConfig(capacity=512, batch_size=10**9), kvs=fkvs)
    rs.attach_flusher(max_staged_versions=10**9,
                      retry=RetryPolicy(max_retries=0))
    _boot_root(rs)
    rs.barrier()                                 # something durable exists
    with rs.writer() as w:
        w.commit([0], adds={100: _payload(1)})
        fkvs.schedule_faults(["transient"])
        with pytest.raises(TransientBackendError):
            rs.barrier()
        with pytest.raises(RuntimeError, match="failed drain"):
            rs.snapshot(mode="pinned")
        rs.barrier()                             # replay lands
        assert rs.snapshot(mode="pinned").staleness_lag == 0


# ------------------------------------------------------------ snapshot modes
def test_fresh_snapshot_is_read_your_writes():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    w = rs.writer()
    v = w.commit([root], adds={100: _payload(1)})
    snap = rs.snapshot()                         # default: drains first
    assert snap.staleness_lag == 0
    assert snap.execute([Q.version(v)])[0].value[100] == _payload(1)
    w.close()


def test_pinned_snapshot_is_stale_but_free():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    rs.barrier()
    w = rs.writer()
    v_staged = w.commit([root], adds={100: _payload(1)})
    p0 = kvs.stats.n_put_queries
    snap = rs.snapshot(mode="pinned")
    assert kvs.stats.n_put_queries == p0         # no drain, no writes
    assert snap.staleness_lag == 1
    # durable versions serve normally; staged ones fail loudly
    assert snap.execute([Q.version(root)])[0].value == {
        pk: _payload(pk) for pk in range(8)}
    with pytest.raises(KeyError):
        snap.execute([Q.version(v_staged)])
    w.close()


def test_pinned_snapshot_without_flusher_reports_pending_lag():
    rs, kvs = _store(batch_size=10**9)
    rs.init_root({pk: _payload(pk) for pk in range(4)})
    rs.flush()
    rs.commit([0], adds={100: _payload(1)})      # pending, unflushed
    snap = rs.snapshot(mode="pinned")
    assert snap.staleness_lag == 1
    assert rs.pending                            # pinned did not flush


def test_snapshot_mode_validation():
    rs, _ = _store()
    with pytest.raises(ValueError, match="unknown snapshot mode"):
        rs.snapshot(mode="stale")


def test_staleness_lag_in_storage_stats():
    rs, _ = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    ing = rs.storage_stats()["ingest"]
    assert ing["mode"] == "async"
    assert ing["staleness_lag"] == 1 and ing["staged_versions"] == 1
    rs.barrier()
    w = rs.writer()
    for i in range(3):
        root = w.commit([root], adds={100 + i: _payload(i)})
    ing = rs.storage_stats()["ingest"]
    assert ing["staleness_lag"] == 3
    assert ing["n_flush_batches"] == 1
    assert ing["n_versions_staged"] == 4
    assert ing["max_observed_lag"] >= 3
    assert ing["open_sessions"] == 1
    w.close()
    rs.barrier()
    ing = rs.storage_stats()["ingest"]
    assert ing["staleness_lag"] == 0 and ing["pending_replay_writes"] == 0


def test_sync_mode_ingest_report():
    rs, _ = _store(batch_size=10**9)
    rs.init_root({pk: _payload(pk) for pk in range(4)})
    ing = rs.storage_stats()["ingest"]
    assert ing["mode"] == "sync"
    assert ing["staged_versions"] == 1 == ing["staleness_lag"]
    assert ing["n_flush_batches"] == 0


# --------------------------------------- no-op conventions and double close
def test_empty_drain_guard_never_touches_backend():
    rs, kvs = _store()
    fl = rs.attach_flusher()
    before = kvs.stats.snapshot()
    for _ in range(3):
        rep = fl.drain()
        assert rep.n_versions == 0 and rep.n_writes == 0
    assert kvs.stats.snapshot() == before


def test_writesession_flush_on_closed_session_is_noop():
    # sync mode
    rs, kvs = _store()
    w = rs.writer()
    w.init_root({pk: _payload(pk) for pk in range(4)})
    w.close()
    before = kvs.stats.snapshot()
    w.flush()                                    # closed: cheap no-op
    assert kvs.stats.snapshot() == before
    # async mode
    rs2, kvs2 = _store()
    rs2.attach_flusher(max_staged_versions=10**9)
    w2 = rs2.writer()
    w2.init_root({pk: _payload(pk) for pk in range(4)})
    w2.close()
    before2 = kvs2.stats.snapshot()
    w2.flush()
    assert kvs2.stats.snapshot() == before2      # no drain from a closed session
    assert rs2.flusher.staleness_lag == 1


def test_writesession_flush_midsession_sync_splits_explicitly():
    rs, kvs = _store()
    w = rs.writer()
    root = w.init_root({pk: _payload(pk) for pk in range(4)})
    w.flush()                                    # deliberate early flush
    assert kvs.stats.n_put_queries == 1
    assert not rs.pending
    v = w.commit([root], adds={100: _payload(1)})
    w.close()                                    # second group commit
    assert kvs.stats.n_put_queries == 2
    assert rs.get_version(v)[0][100] == _payload(1)


def test_writesession_flush_async_is_barrier():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    w = rs.writer()
    w.init_root({pk: _payload(pk) for pk in range(4)})
    w.flush()
    assert kvs.stats.n_flush_batches == 1
    assert rs.flusher.staleness_lag == 0
    w.close()


def test_flusher_double_close_is_noop():
    rs, kvs = _store()
    fl = rs.attach_flusher(max_staged_versions=10**9)
    _boot_root(rs)
    rep = fl.close()                             # final drain + detach
    assert rep.n_versions == 1
    assert rs.flusher is None
    assert fl.close() is None                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        fl.drain()
    # store is back to sync semantics: one-writer rule again
    w = rs.writer()
    with pytest.raises(RuntimeError, match="already open"):
        rs.writer()
    w.close()
    rs.attach_flusher()                          # re-attach works


def test_attach_flusher_guards():
    rs, _ = _store()
    w = rs.writer()
    with pytest.raises(RuntimeError, match="close the open WriteSession"):
        rs.attach_flusher()
    w.close()
    rs.attach_flusher()
    with pytest.raises(RuntimeError, match="already attached"):
        rs.attach_flusher()
    rs3, _ = _store(k=3)
    with pytest.raises(ValueError, match="k == 1"):
        rs3.attach_flusher()
    rs4, _ = _store()
    with pytest.raises(ValueError, match="max_staged_versions"):
        rs4.attach_flusher(max_staged_versions=0)


def test_attach_adopts_pending_versions():
    rs, kvs = _store(batch_size=10**9)
    rs.init_root({pk: _payload(pk) for pk in range(4)})
    rs.commit([0], adds={100: _payload(1)})
    assert len(rs.pending) == 2
    fl = rs.attach_flusher(max_staged_versions=10**9)
    assert fl.staleness_lag == 2                 # adopted into the buffer
    rep = rs.barrier()
    assert rep.n_versions == 2
    assert rs.get_version(1)[0][100] == _payload(1)


def test_commit_after_session_close_still_raises():
    rs, _ = _store()
    rs.attach_flusher()
    w = rs.writer()
    w.init_root({0: _payload(0)})
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.commit([0], adds={1: _payload(1)})


# ----------------------------------------------------- layer composition
def test_cache_write_through_fires_once_per_drained_batch():
    inner = ShardedKVS([InMemoryKVS() for _ in range(2)])
    ckvs = CachingKVS(inner, cache_bytes=4 << 20)
    rs = RStore(RStoreConfig(capacity=512, batch_size=10**9), kvs=ckvs)
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    rs.barrier()
    rs.get_version(root)                         # warm chunk/map keys
    assert ckvs.n_write_through == 0
    w = rs.writer()
    v = root
    for i in range(3):
        v = w.commit([v], adds={100 + i: _payload(i)})
    p0 = ckvs.stats.n_put_queries
    wt0 = ckvs.n_write_through
    rs.barrier()                                 # ONE drained batch
    assert ckvs.stats.n_put_queries - p0 <= 2    # <= one RT per shard
    # previously-cached map keys were re-admitted exactly once, in-batch
    assert ckvs.n_write_through > wt0
    # warm reads after the drain still serve fresh bytes
    got = rs.get_version(v)[0]
    assert got[100 + 2] == _payload(2)
    w.close()


def test_compact_takes_drain_barrier():
    rs, _ = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    rs.barrier()
    w = rs.writer()
    v = root
    for i in range(4):
        v = w.commit([v], adds={i: _payload(50 + i)})
    rep = rs.compact(liveness_threshold=1.0)     # drains staged work first
    assert rs.flusher.staleness_lag == 0
    assert rep.mode in ("online", "noop", "rebuild")
    assert rs.get_version(v)[0][3] == _payload(53)
    w.close()


def test_build_takes_drain_barrier():
    rs, _ = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    w = rs.writer()
    v = w.commit([root], adds={100: _payload(1)})
    rs.build()
    assert rs.flusher.staleness_lag == 0
    assert rs.get_version(v)[0][100] == _payload(1)
    w.close()


def test_retain_takes_drain_barrier():
    rs, _ = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    root = _boot_root(rs)
    w = rs.writer()
    v = root
    for i in range(5):
        v = w.commit([v], adds={100 + i: _payload(i)})
    retired = rs.retain(keep_last(2))
    assert rs.flusher.staleness_lag == 0
    assert retired and root in retired
    assert rs.get_version(v)[0][104] == _payload(4)
    w.close()


# ------------------------------------------------------- KVSStats integration
def test_flusher_counters_ride_stats_protocol():
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=2)
    root = _boot_root(rs)
    w = rs.writer()
    v = root
    for i in range(5):
        v = w.commit([v], adds={100 + i: _payload(i)})
    w.close()
    rs.barrier()
    s = kvs.stats
    assert s.n_versions_staged == 6
    assert s.n_flush_batches >= 2
    assert s.max_observed_lag >= 2
    before = (s.n_flush_batches, s.n_versions_staged, s.max_observed_lag)
    snap = s.snapshot()
    s.reset()
    assert (s.n_flush_batches, s.n_versions_staged, s.max_observed_lag) == (0, 0, 0)
    s.restore(snap)
    assert (s.n_flush_batches, s.n_versions_staged, s.max_observed_lag) == before
    merged = KVSStats.merged([snap, snap])
    assert merged.n_flush_batches == 2 * before[0]
    assert merged.n_versions_staged == 2 * before[1]


def test_storage_stats_does_not_reset_flusher_counters():
    """Regression: metrics calls must not clobber the ingest counters (the
    snapshot/restore bookkeeping pattern other paths use)."""
    rs, kvs = _store()
    rs.attach_flusher(max_staged_versions=2)
    root = _boot_root(rs)
    w = rs.writer()
    for i in range(4):
        root = w.commit([root], adds={100 + i: _payload(i)})
    w.close()
    rs.barrier()
    s = kvs.stats
    before = (s.n_flush_batches, s.n_versions_staged, s.max_observed_lag)
    assert before[0] >= 2
    for _ in range(3):
        rs.storage_stats()
        rs.cache_stats()
    assert (s.n_flush_batches, s.n_versions_staged,
            s.max_observed_lag) == before
    # and a snapshot()'ed report reflects them, not zeros
    assert rs.storage_stats()["ingest"]["n_flush_batches"] == before[0]


# ------------------------------------------------------------ serve gateway
def test_ingest_gateway_multiplexes_clients():
    n_shards = 4
    rs, kvs = _store(n_shards=n_shards)
    gw = IngestGateway(rs, max_staged_versions=10**9)
    root = gw.init_root("alice", {pk: _payload(pk) for pk in range(8)})
    heads = {"alice": root, "bob": root, "carol": root}
    for step in range(4):
        for c in ("alice", "bob", "carol"):
            heads[c] = gw.commit(c, [heads[c]],
                                 adds={hash(c) % 1000 + step: _payload(step)})
    assert kvs.stats.n_put_queries == 0          # all staged
    assert sorted(gw.open_clients) == ["alice", "bob", "carol"]
    rep = gw.barrier()
    assert rep.n_versions == 13
    assert rep.write_round_trips <= n_shards
    r = gw.report()
    assert r["clients"] == {"alice": 5, "bob": 4, "carol": 4}
    assert r["ingest"]["staleness_lag"] == 0
    snap = gw.snapshot()
    for c, v in heads.items():
        assert snap.execute([Q.version(v)])[0].value  # all durable
    gw.close()
    assert rs.flusher is None
    gw.close()                                   # idempotent


def test_ingest_gateway_adopts_existing_flusher():
    rs, _ = _store()
    rs.attach_flusher(max_staged_versions=10**9)
    gw = IngestGateway(rs)
    assert gw.flusher is rs.flusher
    with pytest.raises(ValueError, match="would be ignored"):
        IngestGateway(rs, max_staged_versions=8)
