"""Model zoo correctness: per-arch smoke (reduced configs, one forward/train
step on CPU, shape + finiteness asserts), decode-vs-full-sequence consistency
(validates KV caches, SSD chunked-scan ↔ recurrence duality, cross-attention
caches), blockwise-flash ↔ dense attention equivalence, and MoE dispatch
against a dense-einsum oracle."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, runnable
from repro.models import config as mcfg
from repro.models import layers as L
from repro.models.model import abstract_cache, build_model, init_params

jax.config.update("jax_enable_x64", False)


def make_batch(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("name", list(ARCHS))
def test_arch_smoke_train_step(name):
    """Reduced config: one forward + grad step; shapes and finiteness."""
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, 2, 32, key)

    logits, aux = jax.jit(model.train_logits)(params, batch)
    S_out = 32 + (cfg.n_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("name", list(ARCHS))
def test_arch_decode_matches_full_forward(name):
    """prefill(S0) + teacher-forced decode of the rest == full forward.

    This exercises KV caches, the SSD chunk-scan ↔ step-recurrence duality,
    conv state carry, and cross-attention caches in one shot."""
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S, S0 = 2, 32, 16
    batch = make_batch(cfg, B, S, key)

    full_logits, _ = jax.jit(model.train_logits)(params, batch)
    full_logits = np.asarray(full_logits, np.float32)[..., :cfg.vocab_size]

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S0]
    # enc-dec/vlm: frontend context stays full-length
    logits0, caches = jax.jit(model.prefill)(params, pre_batch)
    P = cfg.n_prefix_embeds if cfg.family == "vlm" else 0

    np.testing.assert_allclose(
        np.asarray(logits0, np.float32)[:, 0, :cfg.vocab_size],
        full_logits[:, P + S0 - 1], rtol=2e-2, atol=2e-3)

    step = jax.jit(model.decode_step)
    for t in range(S0, min(S0 + 4, S)):
        tok = batch["tokens"][:, t:t + 1]
        nxt, caches = step(params, caches, tok, t + (P if cfg.family == "vlm" else 0))
        want = np.argmax(full_logits[:, P + t], axis=-1)
        np.testing.assert_array_equal(np.asarray(nxt), want)


def test_blockwise_attention_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, Hkv, G, dh = 2, 64, 2, 3, 16
    q = jax.random.normal(key, (B, S, Hkv, G, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, dh), jnp.float32)
    for causal in (True, False):
        dense = L._dense_attention(q, k, v, causal=causal, q_offset=0)
        for qb, kb in [(16, 16), (32, 64), (64, 16)]:
            blk = L._blockwise_attention(q, k, v, causal=causal,
                                         q_block=qb, kv_block=kb)
            np.testing.assert_allclose(np.asarray(blk), np.asarray(dense),
                                       rtol=2e-5, atol=2e-5)


def test_ssd_chunk_scan_matches_recurrence():
    """Chunked SSD == naive per-step state recurrence (the duality)."""
    key = jax.random.PRNGKey(3)
    B, S, H, P, N = 2, 32, 3, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)

    for chunk in (4, 8, 16, 32):
        y, final = L._ssd_chunk_scan(x, dt, A, Bm, Cm, chunk)
        # naive recurrence
        state = np.zeros((B, H, P, N), np.float32)
        ys = np.zeros((B, S, H, P), np.float32)
        xs, dts, Bs, Cs = map(np.asarray, (x, dt, Bm, Cm))
        As = np.asarray(A)
        for t in range(S):
            decay = np.exp(dts[:, t] * As)                       # (B,H)
            contrib = np.einsum("bn,bh,bhp->bhpn", Bs[:, t], dts[:, t], xs[:, t])
            state = state * decay[..., None, None] + contrib
            ys[:, t] = np.einsum("bn,bhpn->bhp", Cs[:, t], state)
        np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


def test_moe_matches_dense_oracle_when_capacity_unbounded():
    """Scatter-dispatch MoE == dense one-hot einsum dispatch (no drops)."""
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    cfg = mcfg.ModelConfig(**{**cfg.__dict__, "capacity_factor": 10.0})
    key = jax.random.PRNGKey(4)
    G = 1
    p = L.tree_init(L.moe_defs(cfg, G), key, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p)       # strip layer axis
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model), jnp.float32)

    got, aux = L.moe(p, x, cfg)

    # oracle: dense dispatch
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps).reshape(-1, cfg.d_model)
    probs = jax.nn.softmax((h @ p["router"]).astype(jnp.float32), axis=-1)
    gate, eid = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    up = jnp.einsum("td,edf->tef", h, p["wu"])
    act = jax.nn.silu(jnp.einsum("td,edf->tef", h, p["wg"])) * up
    out_all = jnp.einsum("tef,efd->ted", act, p["wd"])        # every expert
    sel = jnp.take_along_axis(out_all, eid[..., None], axis=1)  # (T,K,D)
    want = x + (sel * gate[..., None]).sum(1).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor → tiny, overflow tokens must be dropped, not
    mis-routed (output stays finite and bounded)."""
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    cfg = mcfg.ModelConfig(**{**cfg.__dict__, "capacity_factor": 0.05})
    p = L.tree_init(L.moe_defs(cfg, 1), jax.random.PRNGKey(0), jnp.float32)
    p = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    got, aux = L.moe(p, x, cfg)
    assert np.isfinite(np.asarray(got)).all()
    assert float(aux) >= 0


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)
    y = L.rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = L.rope(q, jnp.array([i]), 1e4)
        kj = L.rope(k, jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_long_500k_skips_are_exactly_full_attention_archs():
    skipped = {n for n, c in ARCHS.items()
               if not runnable(c, SHAPES["long_500k"])[0]}
    assert skipped == {"internlm2-20b", "smollm-360m", "qwen2.5-32b",
                       "stablelm-1.6b", "whisper-base",
                       "granite-moe-1b-a400m", "kimi-k2-1t-a32b",
                       "internvl2-26b"}
    for n in ("mamba2-130m", "jamba-1.5-large-398b"):
        assert runnable(ARCHS[n], SHAPES["long_500k"])[0]


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_counts_match_assignment(name):
    expected = {
        "mamba2-130m": 0.13e9, "internlm2-20b": 20e9, "smollm-360m": 0.36e9,
        "qwen2.5-32b": 32e9, "stablelm-1.6b": 1.6e9, "whisper-base": 0.074e9,
        "jamba-1.5-large-398b": 398e9, "granite-moe-1b-a400m": 1.3e9,
        "kimi-k2-1t-a32b": 1000e9, "internvl2-26b": 20.9e9}[name]
    got = ARCHS[name].param_count()
    assert 0.55 * expected <= got <= 1.45 * expected, got
