"""Unified query planner: plan IR validation, composite predicate pushdown
(one fused bitmap-VM launch + one interleaved multiget per batch), index-only
and metadata-only aggregates at zero chunk-payload fetches, plan-time
refusal of retired versions, batch-wide leaf dedupe, and explain()."""
import numpy as np
import pytest

from repro.core import (InMemoryKVS, Q, RStore, RStoreConfig, ShardedKVS,
                        keep_last, struct_extractor)
from repro.kernels import ops

N_SHARDS = 4
EXT = struct_extractor({"color": (0, 1), "size": (1, 1)})


def _mk(pk: int, color: int, size: int = 0) -> bytes:
    return bytes([color, size % 251]) + bytes([pk % 251]) * 24


def _make_store(**cfg_kw):
    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs = RStore(RStoreConfig(capacity=1 << 9, batch_size=4, **cfg_kw), kvs=kvs)
    rs.create_index("color", EXT)
    rs.create_index("size", EXT)
    return rs


def _ingest(rs, n_pks=60, n_versions=6):
    vids = []
    with rs.writer() as w:
        v = w.init_root({pk: _mk(pk, pk % 5, pk % 11) for pk in range(n_pks)})
        vids.append(v)
        for i in range(n_versions):
            v = w.commit([v], adds={pk: _mk(pk, (pk + i) % 5, (pk + i) % 11)
                                    for pk in range(i, n_pks, 7)})
            vids.append(v)
    return vids


def _oracle(snap, vid, pred):
    full = snap.execute([Q.version(vid)])[0].value
    return {pk: p for pk, p in full.items() if pred(EXT(p))}


@pytest.fixture()
def store():
    rs = _make_store()
    vids = _ingest(rs)
    return rs, vids, rs.snapshot()


# --------------------------------------------------------- composite results
def test_and_matches_two_session_intersection_byte_identical(store):
    rs, vids, snap = store
    v = vids[-1]
    comp = Q.and_(Q.where(v, "color", 2), Q.where_range(v, "size", 3, 7))
    got = snap.execute([comp])[0].value
    a = snap.execute([Q.where(v, "color", 2)])[0].value
    b = snap.execute([Q.where_range(v, "size", 3, 7)])[0].value
    want = {pk: p for pk, p in a.items() if pk in b and b[pk] == p}
    assert got == want
    assert got == _oracle(snap, v, lambda f: f["color"] == 2
                          and 3 <= f["size"] <= 7)
    assert got                                  # non-vacuous


def test_composite_and_is_one_launch_one_multiget(store):
    rs, vids, snap = store
    v = vids[-1]
    comp = Q.and_(Q.where(v, "color", 1), Q.where_range(v, "size", 2, 9))
    launches0 = ops.BITMAP_LAUNCHES
    res = snap.execute([comp])
    assert ops.BITMAP_LAUNCHES - launches0 == 1
    # one interleaved multiget => at most one round trip per shard
    assert 1 <= res.batch.kvs_queries <= N_SHARDS


def test_or_and_not_match_oracle(store):
    rs, vids, snap = store
    v = vids[-2]
    got_or = snap.execute([Q.or_(Q.where(v, "color", 0),
                                 Q.where(v, "color", 3))])[0].value
    assert got_or == _oracle(snap, v, lambda f: f["color"] in (0, 3))
    got_not = snap.execute(
        [Q.and_(Q.version(v), Q.not_(Q.where(v, "color", 0)))])[0].value
    assert got_not == _oracle(snap, v, lambda f: f["color"] != 0)
    assert got_or and got_not


def test_nested_composite_with_pk_predicates(store):
    rs, vids, snap = store
    v = vids[-1]
    comp = Q.and_(Q.range(v, 10, 40),
                  Q.or_(Q.where(v, "color", 2),
                        Q.and_(Q.where(v, "color", 4),
                               Q.not_(Q.records(v, [12, 19])))))
    got = snap.execute([comp])[0].value
    full = snap.execute([Q.version(v)])[0].value
    want = {pk: p for pk, p in full.items()
            if 10 <= pk <= 40 and (EXT(p)["color"] == 2 or
                                   (EXT(p)["color"] == 4
                                    and pk not in (12, 19)))}
    assert got == want and got


# -------------------------------------------------------------- construction
def test_composite_rejects_mixed_versions(store):
    rs, vids, snap = store
    with pytest.raises(ValueError, match="share one version"):
        Q.and_(Q.where(vids[0], "color", 1), Q.where(vids[1], "color", 1))


def test_composite_rejects_evolution_and_arity():
    with pytest.raises(ValueError, match="predicate"):
        Q.and_(Q.evolution(3), Q.evolution(4))
    with pytest.raises(ValueError, match="at least 2"):
        Q.and_(Q.version(0))
    with pytest.raises(ValueError, match="predicate"):
        Q.count(Q.evolution(3))


def test_retired_version_refused_at_plan_time(store):
    rs, vids, snap = store
    rs.retain(keep_last(2))
    snap = rs.snapshot()
    dead, live = vids[0], vids[-1]
    with pytest.raises(KeyError, match="retired"):
        snap.plan_batch([Q.and_(Q.where(dead, "color", 1),
                                Q.where(dead, "color", 2))])
    with pytest.raises(KeyError, match="retired"):
        snap.plan_batch([Q.count(Q.version(dead))])
    assert snap.execute([Q.version(live)])[0].value   # live ones still fine


def test_where_without_index_raises_at_plan_time(store):
    rs, vids, snap = store
    with pytest.raises(KeyError, match="weight"):
        snap.plan_batch([Q.distinct(vids[-1], "weight")])


# ------------------------------------------------------ index-only aggregates
def test_count_exists_distinct_zero_payload_fetches(store):
    rs, vids, snap = store
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0].value
    res = snap.execute([Q.count(Q.where(v, "color", 2)),
                        Q.exists(Q.where(v, "color", 2)),
                        Q.exists(Q.where(v, "color", 200)),
                        Q.distinct(v, "color")])
    assert res[0].value == sum(1 for p in full.values()
                               if EXT(p)["color"] == 2) > 0
    assert res[1].value is True
    assert res[2].value is False
    assert res[3].value == sorted({EXT(p)["color"] for p in full.values()})
    for r in res:
        assert r.stats.payload_round_trips == 0, r.stats
        assert r.stats.payload_chunks_fetched == 0, r.stats
    assert res.batch.payload_round_trips == 0


def test_count_composite_index_only(store):
    rs, vids, snap = store
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0].value
    q = Q.count(Q.and_(Q.where(v, "color", 1), Q.where_range(v, "size", 0, 5)))
    r = snap.execute([q])
    assert r[0].value == sum(1 for p in full.values()
                             if EXT(p)["color"] == 1 and EXT(p)["size"] <= 5)
    assert r.batch.payload_round_trips == 0


def test_metadata_count_costs_zero_kvs_queries(store):
    rs, vids, snap = store
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0].value
    res = snap.execute([Q.count(Q.version(v)),
                        Q.count(Q.range(v, 5, 25)),
                        Q.exists(Q.records(v, [3, 9]))])
    assert res[0].value == len(full)
    assert res[1].value == sum(1 for pk in full if 5 <= pk <= 25)
    assert res[2].value is True
    assert res.batch.kvs_queries == 0
    assert res.batch.chunks_fetched == 0


# ------------------------------------------------------------- batch behavior
def test_batch_shares_one_launch_and_dedupes_leaves(store):
    rs, vids, snap = store
    v = vids[-1]
    shared = Q.where(v, "color", 2)
    launches0 = ops.BITMAP_LAUNCHES
    res = snap.execute([shared,
                        Q.and_(shared, Q.where_range(v, "size", 3, 7)),
                        Q.count(shared),
                        Q.version(v)])
    assert ops.BITMAP_LAUNCHES - launches0 == 1
    assert res.batch.kvs_queries <= N_SHARDS
    # the dedup'd fetch never pulls a chunk twice: batch total == union
    pqs = snap.plan_batch([shared, Q.and_(shared,
                                          Q.where_range(v, "size", 3, 7)),
                           Q.version(v)])
    union = np.unique(np.concatenate([pq.cand for pq in pqs]))
    assert res.batch.payload_chunks_fetched <= len(union)
    assert res[0].value == _oracle(snap, v, lambda f: f["color"] == 2)


def test_plan_backcompat_returns_candidate_arrays(store):
    rs, vids, snap = store
    v = vids[-1]
    plans = snap.plan([Q.version(v), Q.where(v, "color", 1)])
    assert isinstance(plans, list) and len(plans) == 2
    for cand in plans:
        assert isinstance(cand, np.ndarray)
    assert len(plans[1]) <= len(plans[0])


def test_normalize_flattens_and_cancels_double_negation(store):
    rs, vids, snap = store
    v = vids[-1]
    a, b = Q.where(v, "color", 1), Q.where(v, "color", 2)
    nested = Q.or_(Q.or_(a, b), Q.not_(Q.not_(a)))
    got = snap.execute([nested])[0].value
    assert got == snap.execute([Q.or_(a, b)])[0].value


def test_legacy_kinds_still_route_through_planner(store):
    rs, vids, snap = store
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0].value
    res = snap.execute([Q.record(v, 4), Q.records(v, [1, 2, 999]),
                        Q.range(v, 50, 55), Q.evolution(7)])
    assert res[0].value == full[4]
    assert res[1].value == {1: full[1], 2: full[2]}
    assert res[2].value == {pk: p for pk, p in full.items() if 50 <= pk <= 55}
    evo = res[3].value
    assert [p for _, p in evo][-1] == full[7]


# ------------------------------------------------------------------- explain
def test_explain_reports_mode_and_costs(store):
    rs, vids, snap = store
    v = vids[-1]
    ex = snap.explain([Q.and_(Q.where(v, "color", 2),
                              Q.where_range(v, "size", 3, 7)),
                       Q.count(Q.where(v, "color", 2)),
                       Q.count(Q.version(v))])
    assert [e["mode"] for e in ex] == ["fetch", "index_only", "metadata"]
    for e in ex:
        assert {"plan", "predicted_chunks", "predicted_payload_chunks",
                "predicted_round_trips", "predicted_bytes",
                "predicted_seconds"} <= set(e)
    assert "and" in ex[0]["plan"] and "where" in ex[0]["plan"]
    assert ex[0]["predicted_payload_chunks"] > 0
    assert ex[1]["predicted_payload_chunks"] == 0
    assert ex[2]["predicted_chunks"] == ex[2]["predicted_round_trips"] == 0
    # predictions are honest for the fetch plan: chunk count matches measure
    got = snap.execute([Q.and_(Q.where(v, "color", 2),
                               Q.where_range(v, "size", 3, 7))])
    assert ex[0]["predicted_chunks"] == got[0].stats.chunks_fetched
