
import os
import sys
import types

# 8 host devices: enough for sharding/shard_map tests, cheap enough for the
# rest (the 512-device platform is reserved for launch/dryrun.py)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# --------------------------------------------------------------------------
# hypothesis shim: the property tests require hypothesis (requirements-dev
# .txt), but its absence must not break *collection* of the non-property
# tests in the same modules.  When the real package is missing we install a
# stub whose @given marks the decorated test as skipped; everything else in
# the modules collects and runs normally.
# --------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    class _Strategy:
        """Inert stand-in for a hypothesis strategy object."""

        def __call__(self, *a, **k):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    def _strategy_factory(*a, **k):
        return _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            # no functools.wraps: pytest must see a zero-arg signature, not
            # the strategy-bound parameters of the wrapped property test
            def wrapper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _HealthCheck:
        def __getattr__(self, name):
            return name

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy_factory  # PEP 562
    _st.composite = lambda fn: _strategy_factory

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.HealthCheck = _HealthCheck()
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
