import os
import sys

# 8 host devices: enough for sharding/shard_map tests, cheap enough for the
# rest (the 512-device platform is reserved for launch/dryrun.py)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
