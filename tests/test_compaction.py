"""Background compaction & retention GC: retention policies must prune
versions loudly, a compaction pass must cost one multiput + one multidelete
round trip per touched shard while keeping every retained version
byte-identical, deletes must reclaim device slots and storage stats, and
stale snapshots must re-pin via refresh() rather than die."""
import numpy as np
import pytest

from repro.core import (Compactor, InMemoryKVS, Q, RStore, RStoreConfig,
                        ShardedDeviceKVS, ShardedKVS, keep_all, keep_last,
                        keep_tagged, measure_layout)


def _pay(rng, n=100):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def _churn(rs, rng, n_versions=48, n_keys=24):
    """Root + a chain of single-key updates: the degradation workload (§4
    online appends, most record copies eventually superseded)."""
    v = rs.init_root({k: _pay(rng) for k in range(n_keys)})
    vids = [v]
    for _ in range(n_versions - 1):
        v = rs.commit([v], adds={int(rng.integers(0, n_keys)): _pay(rng)})
        vids.append(v)
    rs.flush()
    return vids


def _kvs_keys(kvs):
    if isinstance(kvs, ShardedKVS):
        out = set()
        for s in kvs.shards:
            out |= set(s._d)
        return out
    return set(kvs._d)


# ------------------------------------------------------------------ retention
def test_retention_policies_resolve():
    rng = np.random.default_rng(0)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    vids = _churn(rs, rng, n_versions=10)
    assert keep_all().resolve(rs.graph) == vids
    assert keep_last(3).resolve(rs.graph) == vids[-3:]
    assert keep_tagged([vids[0], vids[5]]).resolve(rs.graph) == [vids[0], vids[5]]
    with pytest.raises(ValueError, match="k >= 1"):
        keep_last(0).resolve(rs.graph)
    with pytest.raises(ValueError, match="at least one"):
        keep_tagged([]).resolve(rs.graph)
    with pytest.raises(ValueError, match="unknown or already-retired"):
        keep_tagged([999]).resolve(rs.graph)


def test_retired_versions_fail_loudly():
    rng = np.random.default_rng(1)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    vids = _churn(rs, rng, n_versions=8)
    retired = rs.retain(keep_last(3))
    assert retired == vids[:-3]
    # queries against a retired version raise at plan time
    with pytest.raises(KeyError, match="retired"):
        rs.get_version(vids[0])
    with pytest.raises(KeyError, match="retired"):
        rs.get_record(vids[0], 0)
    # committing onto a retired parent raises
    with pytest.raises(ValueError, match="retired"):
        rs.commit([vids[0]], adds={99: _pay(rng)})
    # retained versions unaffected; retirement is idempotent
    assert len(rs.get_version(vids[-1])[0]) > 0
    assert rs.retain(keep_last(3)) == []
    rs.graph.check_invariants()


def test_retain_keep_tagged_of_retired_raises():
    rng = np.random.default_rng(2)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    vids = _churn(rs, rng, n_versions=6)
    rs.retain(keep_last(2))
    with pytest.raises(ValueError, match="already-retired"):
        rs.retain(keep_tagged([vids[0]]))


# ------------------------------------------------------------ compaction pass
def test_compaction_reclaims_and_preserves_content():
    rng = np.random.default_rng(3)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    vids = _churn(rs, rng, n_versions=48)
    keep = vids[-8:]
    oracle = {v: rs.get_version(v)[0] for v in keep}
    before = rs.storage_stats()["stored_chunk_bytes"]

    rs.retain(keep_last(8))
    rep = rs.compact()
    assert rep.mode == "pass" and rep.chunks_deleted > 0
    after = rs.storage_stats()["stored_chunk_bytes"]
    assert after < before
    assert rep.stored_bytes_after == after == kvs.total_stored_bytes() - sum(
        len(kvs._d[f"map/{c}"]) for c in rs._chunk_records)
    # retained versions byte-identical through the rewritten layout
    for v in keep:
        assert rs.get_version(v)[0] == oracle[v]
    # the KVS holds exactly the indexed keys — nothing orphaned, nothing lost
    want = {k for c in rs._chunk_records for k in (f"chunk/{c}", f"map/{c}")}
    assert _kvs_keys(kvs) == want
    rs.graph.check_invariants()


def test_compaction_round_trips_one_per_touched_shard():
    """The ci.sh gate contract: a pass = one multiput round trip per shard
    its writes touch + one multidelete round trip per shard its deletes
    touch, however many chunks move."""
    rng = np.random.default_rng(4)
    kvs = ShardedKVS([InMemoryKVS() for _ in range(4)])
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    _churn(rs, rng, n_versions=48)
    rs.retain(keep_last(8))

    puts0 = [s.stats.n_put_queries for s in kvs.shards]
    dels0 = [s.stats.n_delete_queries for s in kvs.shards]
    rep = rs.compact()
    assert rep.mode == "pass"
    dput = [s.stats.n_put_queries - p for s, p in zip(kvs.shards, puts0)]
    ddel = [s.stats.n_delete_queries - d for s, d in zip(kvs.shards, dels0)]
    assert all(d <= 1 for d in dput) and all(d <= 1 for d in ddel)
    assert rep.write_round_trips == sum(dput) >= 1
    assert rep.delete_round_trips == sum(ddel) >= 1


def test_compaction_noop_costs_zero_round_trips():
    rng = np.random.default_rng(5)
    kvs = InMemoryKVS()
    # big capacity → one well-packed chunk; no retention → nothing to do
    rs = RStore(RStoreConfig(capacity=1 << 20, batch_size=8), kvs=kvs)
    _churn(rs, rng, n_versions=8)
    s0 = kvs.stats.snapshot()
    rep = rs.compact()
    assert rep.mode == "noop"
    assert kvs.stats.n_put_queries == s0.n_put_queries
    assert kvs.stats.n_delete_queries == s0.n_delete_queries


def test_lone_small_chunk_not_churned():
    """A single small chunk has no merge partner: rewriting it would be
    pure churn, so a fully-live single-chunk store is a no-op."""
    rng = np.random.default_rng(6)
    rs = RStore(RStoreConfig(capacity=1 << 20, batch_size=4))
    rs.init_root({k: _pay(rng) for k in range(4)})
    rs.flush()
    assert rs.compact().mode == "noop"


def test_compaction_k3_falls_back_to_rebuild():
    rng = np.random.default_rng(7)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8, k=3), kvs=kvs)
    vids = _churn(rs, rng, n_versions=24)
    keep = vids[-4:]
    oracle = {v: rs.get_version(v)[0] for v in keep}
    before = kvs.total_stored_bytes()
    rs.retain(keep_last(4))
    rep = rs.compact()
    assert rep.mode == "rebuild"
    assert kvs.total_stored_bytes() < before
    for v in keep:
        assert rs.get_version(v)[0] == oracle[v]
    want = {k for c in rs._chunk_records for k in (f"chunk/{c}", f"map/{c}")}
    assert _kvs_keys(kvs) == want


def test_build_deletes_stale_chunk_keys():
    """A rebuild that shrinks the chunk count must GC the now-unreferenced
    chunk/map keys (pre-existing leak, observable after retention)."""
    rng = np.random.default_rng(8)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=1024, batch_size=4), kvs=kvs)
    _churn(rs, rng, n_versions=32)
    rs.retain(keep_last(2))
    rs.build()
    want = {k for c in rs._chunk_records for k in (f"chunk/{c}", f"map/{c}")}
    assert _kvs_keys(kvs) == want


# ----------------------------------------------------- snapshots across passes
def test_snapshot_refresh_repins_after_compaction():
    rng = np.random.default_rng(9)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    vids = _churn(rs, rng, n_versions=32)
    snap = rs.snapshot()
    keep = vids[-6:]
    oracle = {v: snap.execute([Q.version(v)])[0].value for v in keep}

    rs.retain(keep_last(6))
    rep = rs.compact()
    assert rep.mode == "pass"
    with pytest.raises(RuntimeError, match="refresh"):
        snap.execute([Q.version(keep[0])])
    assert snap.refresh() is snap            # re-pin, same object
    for v in keep:
        assert snap.execute([Q.version(v)])[0].value == oracle[v]


def test_snapshot_refresh_cannot_survive_build():
    rng = np.random.default_rng(10)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    _churn(rs, rng, n_versions=8)
    snap = rs.snapshot()
    rs.build()
    with pytest.raises(RuntimeError, match="new snapshot"):
        snap.refresh()
    with pytest.raises(RuntimeError, match="rebuild"):
        snap.execute([Q.version(0)])


def test_compact_during_open_writer_raises():
    rng = np.random.default_rng(11)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9))
    with rs.writer() as w:
        w.init_root({k: _pay(rng) for k in range(8)})
        with pytest.raises(RuntimeError, match="group commit"):
            rs.compact()
        with pytest.raises(RuntimeError, match="group commit"):
            rs.retain(keep_last(1))


def test_retain_respects_auto_flush_contract():
    rng = np.random.default_rng(12)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9,
                             auto_flush=False))
    rs.init_root({k: _pay(rng) for k in range(8)})
    with pytest.raises(RuntimeError, match="unflushed"):
        rs.retain(keep_last(1))
    with pytest.raises(RuntimeError, match="unflushed"):
        rs.compact()
    rs.flush()
    assert rs.retain(keep_last(1)) == []


# ------------------------------------------------------- evolution semantics
def test_evolution_hides_dead_records_before_and_after_compaction():
    """Q3 must return only record copies reachable from retained versions —
    including dead copies still physically present in kept chunks."""
    rng = np.random.default_rng(13)
    rs = RStore(RStoreConfig(capacity=1 << 16, batch_size=4))
    v0 = rs.init_root({0: _pay(rng), 1: _pay(rng)})
    v1 = rs.commit([v0], adds={0: _pay(rng)})
    v2 = rs.commit([v1], adds={0: _pay(rng)})
    rs.flush()
    assert [o for o, _ in rs.get_evolution(0)[0]] == [v0, v1, v2]

    rs.retain(keep_last(1))           # only v2 retained
    # before any compaction: dead copies are filtered via chunk-map bitmaps
    assert [o for o, _ in rs.get_evolution(0)[0]] == [v2]
    rs.compact(liveness_threshold=1.0)
    assert [o for o, _ in rs.get_evolution(0)[0]] == [v2]
    # pk 1 is live in v2 (inherited) — still visible
    assert [o for o, _ in rs.get_evolution(1)[0]] == [v0]


# ------------------------------------------------------------ layout health
def test_layout_health_metrics():
    rng = np.random.default_rng(14)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8))
    _churn(rs, rng, n_versions=40)
    h = measure_layout(rs)
    assert h.n_chunks == rs.storage_stats()["n_chunks"]
    assert h.stored_bytes == rs.storage_stats()["stored_chunk_bytes"]
    assert h.n_dead_records == 0 and h.dead_frac == 0.0
    assert all(lv == 1.0 for lv in h.chunk_liveness.values())
    assert h.frag_score >= 1.0 and h.span_factor >= 1.0
    assert h.est_read_seconds >= h.est_read_seconds_ideal > 0
    assert int(h.size_histogram[0].sum()) == h.n_chunks
    assert h.model["version_queries"] > 0

    rs.retain(keep_last(4))
    h2 = measure_layout(rs)
    assert h2.n_dead_records > 0 and h2.dead_frac > 0
    cp = Compactor(rs)
    assert cp.should_run(h2)          # plenty of dead bytes → trigger
    rep = cp.run_pass()
    h3 = measure_layout(rs)
    assert h3.stored_bytes < h2.stored_bytes
    assert h3.frag_score <= h2.frag_score
    assert rep.records_dropped > 0


# -------------------------------------------------- multidelete (satellites)
@pytest.mark.parametrize("make", [
    InMemoryKVS,
    lambda: ShardedKVS([InMemoryKVS(), InMemoryKVS()]),
    lambda: ShardedDeviceKVS(slot_bytes=64, n_slots=8),
])
def test_empty_multidelete_costs_zero_round_trips(make):
    kvs = make()
    kvs.multidelete([])
    assert kvs.stats.n_delete_queries == 0
    assert kvs.stats.n_keys_deleted == 0


@pytest.mark.parametrize("make", [
    InMemoryKVS,
    lambda: ShardedKVS([InMemoryKVS(), InMemoryKVS(), InMemoryKVS()]),
])
def test_multidelete_roundtrip_and_stats(make):
    kvs = make()
    items = [(f"k{i}", bytes([i]) * (i + 1)) for i in range(12)]
    kvs.multiput(items)
    kvs.multidelete([k for k, _ in items[:8]])
    assert kvs.stats.n_keys_deleted == 8
    assert all(k not in kvs for k, _ in items[:8])
    assert all(k in kvs for k, _ in items[8:])
    assert kvs.total_stored_bytes() == sum(len(v) for _, v in items[8:])
    with pytest.raises(KeyError):
        kvs.multidelete(["k0"])       # double delete is an ownership bug
    if isinstance(kvs, ShardedKVS):
        # one round trip per shard touched
        assert kvs.stats.n_delete_queries <= len(kvs.shards)
        assert kvs.stats.n_delete_queries == sum(
            1 for s in kvs.shards if s.stats.n_delete_queries)


def test_device_kvs_multidelete_reclaims_slots():
    """Deleted values must return their extents to the free list and stop
    counting toward total_stored_bytes (no double-counting forever)."""
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=8)
    kvs.multiput([("a", b"x" * 60), ("b", b"y" * 130), ("c", b"z" * 64)])
    assert kvs.total_stored_bytes() == 60 + 130 + 64
    high = kvs.high_water_slots
    kvs.multidelete(["a", "b"])
    assert kvs.stats.n_delete_queries == 1 and kvs.stats.n_keys_deleted == 2
    assert kvs.total_stored_bytes() == 64
    assert kvs.free_slots == 4                  # 1 ("a") + 3 ("b") coalesced
    assert "a" not in kvs and "c" in kvs
    # freed extents are reused before growing the table
    kvs.multiput([("d", b"w" * 250)])           # 4 slots — fits the hole
    assert kvs.high_water_slots == high
    assert kvs.get("d") == b"w" * 250
    with pytest.raises(KeyError):
        kvs.delete("a")


def test_device_kvs_backed_store_compaction_shrinks_footprint():
    """End to end on the device backend: compaction must shrink the live
    slot footprint (deletes feed the free list, later writes reuse it)."""
    rng = np.random.default_rng(15)
    kvs = ShardedDeviceKVS(slot_bytes=256, n_slots=64)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    vids = _churn(rs, rng, n_versions=40)
    stored_before = kvs.total_stored_bytes()
    oracle = rs.get_version(vids[-1])[0]
    rs.retain(keep_last(4))
    rep = rs.compact()
    assert rep.mode == "pass"
    assert kvs.total_stored_bytes() < stored_before
    assert kvs.free_slots > 0 or kvs.high_water_slots < stored_before // 256
    assert rs.get_version(vids[-1])[0] == oracle


def test_stats_snapshot_restore_merge_cover_delete_counters():
    from repro.core import KVSStats
    a = KVSStats(n_queries=1, n_delete_queries=3, n_keys_deleted=7)
    b = a.snapshot()
    assert b.n_delete_queries == 3 and b.n_keys_deleted == 7
    m = KVSStats.merged([a, b])
    assert m.n_delete_queries == 6 and m.n_keys_deleted == 14
    a.reset()
    assert a.n_delete_queries == 0 and a.n_keys_deleted == 0
    a.restore(b)
    assert a.n_delete_queries == 3
    # deletes price per-request overhead in the write-side cost model
    assert KVSStats(n_delete_queries=2).simulated_write_seconds(1e-3, 1e9) \
        == pytest.approx(2e-3)


# ---------------------------------------------------------- checkpointer GC
def test_checkpointer_retain_last_caps_storage():
    from repro.train.checkpoint import VersionedCheckpointer

    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=4096, batch_size=4), kvs=kvs)
    ck = VersionedCheckpointer(store=rs, block_bytes=512)
    rng = np.random.default_rng(16)
    state = {"w": rng.normal(size=(64, 8)).astype(np.float32)}
    vids = []
    for i in range(12):
        w = state["w"].copy()
        w[i % 64, :] += 1.0           # one dirty block per step
        state = {"w": w}
        vids.append(ck.commit(state, parents=vids[-1:] or ()))
    before = rs.storage_stats()["stored_chunk_bytes"]
    rep = ck.retain_last(3)
    assert rep is not None and rep.mode in ("pass", "noop")
    assert rs.storage_stats()["stored_chunk_bytes"] <= before
    assert set(ck.meta) == set(vids[-3:])    # metas of dropped versions gone
    got = ck.restore(vids[-1])
    np.testing.assert_array_equal(got["w"], state["w"])
    with pytest.raises(KeyError, match="retired"):
        ck.restore(vids[0])


def test_checkpointer_retain_tagged_pins_milestones():
    from repro.train.checkpoint import VersionedCheckpointer

    rs = RStore(RStoreConfig(capacity=4096, batch_size=4))
    ck = VersionedCheckpointer(store=rs, block_bytes=512)
    rng = np.random.default_rng(17)
    state = {"w": rng.normal(size=(32, 8)).astype(np.float32)}
    vids = []
    for i in range(8):
        state = {"w": state["w"] + 1.0}
        vids.append(ck.commit(state, parents=vids[-1:] or (),
                              tag=f"step{i}" if i % 4 == 0 else ""))
    assert ck.tags == {"step0": vids[0], "step4": vids[4]}
    want = ck.restore(vids[4])
    rep = ck.retain_tagged(["step0", "step4"])
    assert rep is not None
    assert set(ck.meta) == {vids[0], vids[4]}
    np.testing.assert_array_equal(ck.restore(vids[4])["w"], want["w"])
    with pytest.raises(KeyError, match="retired"):
        ck.restore(vids[1])
    # dropped versions' tags vanish with them; unknown tags raise
    rep2 = ck.retain_tagged(["step4"])
    assert ck.tags == {"step4": vids[4]}
    with pytest.raises(KeyError, match="unknown checkpoint tag"):
        ck.retain_tagged(["step0"])
