"""Tests for the §Perf beyond-paper features: shard_map MoE equivalence,
sharding profiles/rules, custom-VJP rmsnorm gradients, and the HLO analyzer
(trip-count multiplication + slice-aware byte model)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import config as mcfg
from repro.models import layers as L
from repro.sharding.rules import default_rules, dp_only_rules, mesh_env


def _mesh(shape=(2, 4), axes=("data", "model")):
    if np.prod(shape) > jax.device_count():
        pytest.skip(f"needs {np.prod(shape)} devices")
    from repro.launch.mesh import _make_mesh   # shared AxisType compat
    return _make_mesh(shape, axes)


@pytest.fixture(scope="module", autouse=True)
def _devices():
    # tests in this module run on whatever devices exist; CI sets
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 via tests/conftest
    return jax.devices()


def test_moe_shard_map_matches_oracle():
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    cfg = mcfg.ModelConfig(**{**cfg.__dict__, "capacity_factor": 8.0,
                              "n_experts": 8})
    mesh = _mesh()
    p = L.tree_init(L.moe_defs(cfg, 1), jax.random.PRNGKey(0), jnp.float32)
    p = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    want, _ = L.moe(p, x, cfg)
    with mesh_env(mesh):
        got, _ = jax.jit(lambda p, x: L.moe_shard_map(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_shard_map_gradients_flow():
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    cfg = mcfg.ModelConfig(**{**cfg.__dict__, "capacity_factor": 8.0,
                              "n_experts": 8})
    mesh = _mesh()
    p = L.tree_init(L.moe_defs(cfg, 1), jax.random.PRNGKey(0), jnp.float32)
    p = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)

    def loss_sm(p, x):
        with mesh_env(mesh):
            y, aux = L.moe_shard_map(p, x, cfg)
        return jnp.sum(y * y) + aux

    def loss_ref(p, x):
        y, aux = L.moe(p, x, cfg)
        return jnp.sum(y * y) + aux

    with mesh_env(mesh):
        g_sm = jax.jit(jax.grad(loss_sm))(p, x)
    g_ref = jax.grad(loss_ref)(p, x)
    for a, b in zip(jax.tree.leaves(g_sm), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_rmsnorm_custom_vjp_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32), jnp.float32)
    s = jax.random.normal(jax.random.PRNGKey(1), (32,)) * 0.1 + 1.0

    def f_custom(x, s):
        return jnp.sum(jnp.sin(L.rmsnorm(x, s, 1e-5)))

    def f_ref(x, s):
        return jnp.sum(jnp.sin(L._rmsnorm_ref(x, s, 1e-5)))

    gx1, gs1 = jax.grad(f_custom, argnums=(0, 1))(x, s)
    gx2, gs2 = jax.grad(f_ref, argnums=(0, 1))(x, s)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gs1), np.asarray(gs2),
                               rtol=1e-4, atol=1e-5)


def test_rules_drop_indivisible_assignments():
    mesh = _mesh((1, 4), ("data", "model"))
    from repro.sharding.rules import MeshEnv
    env = MeshEnv(mesh, default_rules(mesh))
    # 15 heads over 4-way model axis: dropped → replicated
    spec = env.spec_for((960, 15, 64), ("embed", "heads", "head_dim"))
    assert spec[1] is None
    # 16 heads: sharded
    spec = env.spec_for((960, 16, 64), ("embed", "heads", "head_dim"))
    assert spec[1] == "model"


def test_dp_only_rules_use_all_axes_for_batch():
    mesh = _mesh((2, 4), ("data", "model"))
    rules = dp_only_rules(mesh)
    assert rules["batch"] == ("data", "model")
    assert rules["mlp"] == ()


# ------------------------------------------------------------ hlo analyzer
def test_hlo_analyzer_multiplies_scan_bodies():
    from benchmarks.hlo_analysis import analyze_text

    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, a, ws)[0]

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)).compile()
    costs = analyze_text(c.as_text())
    want = 7 * 2 * 128 ** 3
    assert abs(costs.flops - want) / want < 0.01
    # XLA's own analysis undercounts (visits the body once) — the reason
    # this analyzer exists
    from benchmarks.hlo_analysis import xla_cost_analysis
    assert xla_cost_analysis(c)["flops"] < costs.flops


def test_hlo_analyzer_slice_aware_bytes():
    from benchmarks.hlo_analysis import analyze_text

    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, a, ws)[0]

    n = 50
    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)).compile()
    costs = analyze_text(c.as_text())
    # each iteration is charged ~a few tensor slices (weight r+w, dot out,
    # carry copies ≈ 0.5 MB) — NOT the whole (n, 128, 128) stack (3.2 MB/iter
    # at n=50, which the pre-fix model charged)
    per_iter = costs.bytes / n
    assert per_iter < 16 * 128 * 128 * 4
    assert per_iter < n * 128 * 128 * 4 / 2


def test_hlo_analyzer_collective_multiplicity():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks.hlo_analysis import analyze_text
    mesh = _mesh((4,), ("model",))

    def body_fn(a, ws):
        def body(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None))), None
        return jax.lax.scan(body, a, ws)[0]

    c = jax.jit(body_fn).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None))),
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None, "model")))
    ).compile()
    costs = analyze_text(c.as_text())
    mults = [col.get("mult", 1) for col in costs.collectives]
    assert any(m == 5 for m in mults)
