"""Replication & fault-tolerance layer: error taxonomy, fault injection,
retry/failover reads, quorum writes, read-repair, and shard recovery."""
import dataclasses

import numpy as np
import pytest

from repro.core import (InMemoryKVS, KVSStats, Q, RStore, RStoreConfig,
                        ShardedKVS, keep_last)
from repro.core.replica import (BackendTimeout, BackendUnavailable,
                                FaultInjectingKVS, QuorumLost,
                                RecoveryManager, ReplicatedKVS, RetryPolicy,
                                ShardDown, TransientBackendError)


def _group(n=2, quorum=1, retry=None, **fault_kw):
    reps = [FaultInjectingKVS(InMemoryKVS(), seed=100 + i, **fault_kw)
            for i in range(n)]
    return ReplicatedKVS(reps, write_quorum=quorum, retry=retry), reps


# ------------------------------------------------------------- stats guards
def test_kvsstats_fields_drift_guard():
    """_FIELDS is now DERIVED from dataclasses.fields(), so a new counter can
    never silently drop out of merged/snapshot/reset/restore — the guard only
    checks ordering (declaration order is the stable iteration order) and
    that every declared field actually round-trips."""
    declared = tuple(f.name for f in dataclasses.fields(KVSStats))
    assert KVSStats._FIELDS == declared
    for f in ("n_cache_hits", "n_cache_misses", "bytes_served_from_cache",
              "n_flush_batches", "n_versions_staged", "max_observed_lag"):
        assert f in KVSStats._FIELDS
    s = KVSStats(**{name: i + 1 for i, name in enumerate(declared)})
    snap = s.snapshot()
    assert all(getattr(snap, f) == getattr(s, f) for f in declared)
    m = KVSStats.merged([s, s])
    assert all(getattr(m, f) == 2 * getattr(s, f) for f in declared)
    s.reset()
    assert all(getattr(s, f) == 0 for f in declared)


def test_kvsstats_new_counters_roundtrip():
    s = KVSStats(n_retries=3, n_failovers=2, simulated_backoff_seconds=0.25)
    snap = s.snapshot()
    assert (snap.n_retries, snap.n_failovers) == (3, 2)
    assert snap.simulated_backoff_seconds == pytest.approx(0.25)
    m = KVSStats.merged([s, s])
    assert m.n_retries == 6 and m.n_failovers == 4
    assert m.simulated_backoff_seconds == pytest.approx(0.5)
    s.reset()
    assert s.n_retries == 0 and s.simulated_backoff_seconds == 0


# ----------------------------------------------------------- KeyError names
def test_inmemory_keyerror_names_missing_key():
    kvs = InMemoryKVS()
    kvs.put("present", b"x")
    for fn in (lambda: kvs.get("gone/7"),
               lambda: kvs.multiget(["present", "gone/7"]),
               lambda: kvs.multidelete(["gone/7"])):
        with pytest.raises(KeyError) as ei:
            fn()
        assert "gone/7" in str(ei.value)
    # and a miss is NOT a BackendUnavailable — failover must not eat it
    with pytest.raises(KeyError):
        kvs.get("gone/7")
    assert not issubclass(KeyError, BackendUnavailable)


# ------------------------------------------------------------- retry policy
def test_retry_backoff_capped_and_deterministic():
    p = RetryPolicy(base_delay_s=0.01, max_delay_s=0.1, multiplier=2.0,
                    jitter_frac=0.1, seed=7)
    delays = [p.backoff(a) for a in range(1, 10)]
    assert all(d <= 0.1 * 1.1 + 1e-12 for d in delays)
    assert delays[0] < delays[3]                      # grows before the cap
    assert delays == [RetryPolicy(base_delay_s=0.01, max_delay_s=0.1,
                                  multiplier=2.0, jitter_frac=0.1,
                                  seed=7).backoff(a) for a in range(1, 10)]
    # jitter stays within ±jitter_frac of the raw exponential
    assert 0.009 <= delays[0] <= 0.011


def test_retry_recovers_transient_and_counts():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientBackendError("blip")
        return "ok"

    stats = KVSStats()
    assert RetryPolicy(max_retries=4).call(flaky, stats) == "ok"
    assert stats.n_retries == 2
    assert stats.simulated_backoff_seconds > 0


def test_retry_gives_up_and_never_retries_sharddown():
    stats = KVSStats()
    with pytest.raises(BackendTimeout):
        RetryPolicy(max_retries=2).call(
            lambda: (_ for _ in ()).throw(BackendTimeout("t")), stats)
    assert stats.n_retries == 2

    calls = {"n": 0}

    def down():
        calls["n"] += 1
        raise ShardDown("dead")

    with pytest.raises(ShardDown):
        RetryPolicy(max_retries=5).call(down, stats)
    assert calls["n"] == 1                            # no retry on hard-down


# ---------------------------------------------------------- fault injection
def test_fault_schedule_is_deterministic():
    def trace(seed):
        f = FaultInjectingKVS(InMemoryKVS(), seed=seed, p_transient=0.4,
                              p_timeout=0.2, max_consecutive_faults=3)
        out = []
        for i in range(40):
            try:
                f.multiput([(f"k{i}", b"v")])
                out.append("ok")
            except TransientBackendError:
                out.append("transient")
            except BackendTimeout:
                out.append("timeout")
        return out

    a, b = trace(5), trace(5)
    assert a == b
    assert trace(6) != a                       # different seed, different run
    assert "transient" in a and "timeout" in a and "ok" in a


def test_fault_injection_bounds_consecutive_faults():
    f = FaultInjectingKVS(InMemoryKVS(), seed=1, p_transient=1.0,
                          max_consecutive_faults=2)
    outcomes = []
    for i in range(9):
        try:
            f.multiput([(f"k{i}", b"v")])
            outcomes.append(True)
        except TransientBackendError:
            outcomes.append(False)
    # with p=1, the pattern is exactly fail, fail, forced success, ...
    assert outcomes == [False, False, True] * 3


def test_schedule_faults_deterministic_queue():
    """schedule_faults() consumes verbatim before the probability stream and
    ignores the consecutive-fault bound — the interleaving harness's hook."""
    f = FaultInjectingKVS(InMemoryKVS(), seed=4, max_consecutive_faults=1)
    f.schedule_faults(["transient", "transient", "timeout", "ok"])
    with pytest.raises(TransientBackendError):
        f.multiput([("a", b"1")])
    with pytest.raises(TransientBackendError):   # bound does not apply
        f.multiput([("a", b"1")])
    with pytest.raises(BackendTimeout):
        f.multiput([("a", b"1")])
    assert f.inner.get("a") == b"1"              # timeout applied first
    f.multiput([("b", b"2")])                    # scheduled "ok"
    f.multiput([("c", b"3")])                    # queue empty, p=0: clean
    assert f.n_transient_injected == 2 and f.n_timeouts_injected == 1
    with pytest.raises(ValueError, match="unknown fault kind"):
        f.schedule_faults(["bogus"])


def test_timeout_write_is_applied_then_raises():
    f = FaultInjectingKVS(InMemoryKVS(), seed=2, p_timeout=1.0,
                          max_consecutive_faults=1)
    with pytest.raises(BackendTimeout):
        f.multiput([("k", b"payload")])
    assert f.inner.get("k") == b"payload"      # the write landed; ack lost
    f.multiput([("k2", b"x")])                 # forced success after the cap
    # deletes fault BEFORE applying (not idempotent), so a retry never
    # deletes twice
    with pytest.raises(BackendTimeout):
        f.multidelete(["k"])
    assert "k" in f.inner


def test_kill_and_revive():
    f = FaultInjectingKVS(InMemoryKVS(), seed=3)
    f.put("k", b"v")
    f.kill()
    for fn in (lambda: f.get("k"), lambda: f.multiput([("a", b"b")]),
               lambda: f.scan(), lambda: "k" in f,
               lambda: f.total_stored_bytes()):
        with pytest.raises(ShardDown):
            fn()
    assert f.n_down_rejections == 5
    f.revive()
    assert f.get("k") == b"v"                  # stale-but-answering


# ------------------------------------------------------------ replica group
def test_replicated_writes_fan_out_and_reads_prefer_one():
    g, reps = _group(n=3)
    g.multiput([("a", b"1"), ("b", b"2")])
    for r in reps:
        # peek at the raw dict — r.inner.get() would count read stats
        assert r.inner._d == {"a": b"1", "b": b"2"}
    assert g.multiget(["a", "b"]) == [b"1", b"2"]
    # reads hit only the preferred replica (no fan-out read amplification)
    assert reps[0].stats.n_queries >= 1
    assert reps[1].stats.n_queries == 0 and reps[2].stats.n_queries == 0
    assert "a" in g and "zzz" not in g
    g.multidelete(["a"])
    for r in reps:
        assert "a" not in r.inner
    assert g.total_stored_bytes() == 1         # logical bytes, one copy


def test_replicated_missing_key_is_not_a_failover():
    g, _ = _group(n=2)
    g.put("a", b"1")
    with pytest.raises(KeyError) as ei:
        g.multiget(["a", "nope"])
    assert "nope" in str(ei.value)
    assert g.stats.n_failovers == 0


def test_read_failover_costs_one_extra_round_trip_once():
    g, reps = _group(n=2)
    g.multiput([(f"k{i}", bytes([i])) for i in range(8)])
    reps[0].kill()
    q0 = g.stats.n_queries
    assert g.multiget(["k1", "k2"]) == [b"\x01", b"\x02"]
    # first degraded batch: failed attempt on the dead replica + the
    # successful failover = exactly one extra round trip
    assert g.stats.n_queries - q0 == 2
    assert g.stats.n_failovers == 1
    assert g.live == (False, True)
    q1 = g.stats.n_queries
    assert g.get("k3") == b"\x03"
    # known-down replica is skipped at zero cost from now on
    assert g.stats.n_queries - q1 == 1
    assert g.stats.n_failovers == 1


def test_all_replicas_down_raises_shard_down():
    g, reps = _group(n=2)
    g.put("k", b"v")
    for r in reps:
        r.kill()
    with pytest.raises(ShardDown):
        g.multiget(["k"])
    with pytest.raises(QuorumLost):
        g.multiput([("x", b"y")])


def test_write_quorum_enforced():
    g, reps = _group(n=3, quorum=2)
    g.put("a", b"1")
    reps[2].kill()
    g.put("b", b"2")                           # 2 of 3 acks: fine
    reps[1].kill()
    with pytest.raises(QuorumLost):
        g.put("c", b"3")                       # 1 of 3 acks < quorum 2
    # the quorum-failed write still reached the survivor and the repair
    # logs of the dead replicas — recovery converges, never loses acks
    assert reps[0].inner.get("c") == b"3"
    assert g.pending_repairs(1) >= 1 and g.pending_repairs(2) >= 1


def test_missed_writes_are_read_repaired_on_failover():
    g, reps = _group(n=2)
    g.multiput([("a", b"old"), ("b", b"1")])
    reps[1].kill()
    g.multiput([("a", b"new"), ("c", b"2")])   # replica 1 misses this
    g.multidelete(["b"])                       # ...and this
    assert g.pending_repairs(1) == 3
    reps[1].revive()
    g.mark_live(1)                             # back in rotation, log intact
    reps[0].kill()                             # force reads onto replica 1
    assert g.multiget(["a", "c"]) == [b"new", b"2"]   # backfilled first
    assert g.pending_repairs(1) == 0
    assert "b" not in reps[1].inner            # missed delete applied too
    with pytest.raises(KeyError):
        g.get("b")


def test_put_then_delete_missed_entirely_leaves_no_phantom():
    g, reps = _group(n=2)
    reps[1].kill()
    g.put("tmp", b"x")
    g.multidelete(["tmp"])                     # replica 1 never saw "tmp"
    reps[1].revive()
    g.mark_live(1)
    reps[0].kill()
    assert "tmp" not in g                      # tombstone; no KeyError crash
    assert "tmp" not in reps[1].inner


# ---------------------------------------------------------------- recovery
def test_rebuild_restores_replica_and_read_rotation():
    g, reps = _group(n=2)
    g.multiput([(f"k{i}", bytes([i]) * 4) for i in range(10)])
    reps[0].kill()
    g.multiput([("k3", b"updated"), ("new", b"fresh")])
    g.multidelete(["k7"])
    assert g.preferred == 1 or g.get("k0")     # reads moved off replica 0
    reps[0].revive()                           # stale: old k3/k7, no "new"
    rep = RecoveryManager(g).rebuild(0)
    assert rep.source == 1
    assert rep.stale_keys_deleted == 1         # k7
    assert rep.keys_copied == 2                # k3 (changed) + new (missing)
    assert rep.read_round_trips == 2 and rep.round_trips <= 4
    assert dict(reps[0].inner.scan()) == dict(reps[1].inner.scan())
    assert g.live == (True, True) and g.preferred == 0
    q0 = reps[0].stats.n_queries
    assert g.get("k3") == b"updated"
    assert reps[0].stats.n_queries == q0 + 1   # served by the rebuilt replica


def test_rebuild_from_total_loss_via_fresh_replacement():
    g, reps = _group(n=3)
    g.multiput([(f"k{i}", b"v%d" % i) for i in range(6)])
    reps[1].kill()
    g.put("late", b"z")
    fresh = FaultInjectingKVS(InMemoryKVS(), seed=999)
    g.replicas[1] = fresh                      # disk gone; new empty node
    rep = RecoveryManager(g).rebuild(1)
    assert rep.keys_copied == 7 and rep.stale_keys_deleted == 0
    assert dict(fresh.inner.scan()) == dict(reps[0].inner.scan())
    assert g.live == (True, True, True)


def test_rebuild_needs_a_live_survivor_and_reachable_target():
    g, reps = _group(n=2)
    g.put("k", b"v")
    reps[0].kill()
    reps[1].kill()
    g.mark_down(1)
    with pytest.raises(ShardDown):
        RecoveryManager(g).rebuild(0)          # no survivor
    reps[1].revive()
    g.mark_live(1)
    with pytest.raises(ShardDown):
        RecoveryManager(g).rebuild(0)          # target still down
    reps[0].revive()
    RecoveryManager(g).rebuild(0)
    assert g.live == (True, True)


def test_recover_all_over_sharded_router():
    shards = [ReplicatedKVS([FaultInjectingKVS(InMemoryKVS(), seed=i * 2 + r)
                             for r in range(2)]) for i in range(3)]
    kvs = ShardedKVS(shards)
    kvs.multiput([(f"key/{i}", bytes([i])) for i in range(30)])
    for g in shards:
        g.replicas[0].kill()
    kvs.multiput([(f"key/{i}", bytes([i]) * 2) for i in range(5)])
    for g in shards:
        g.replicas[0].revive()
    reports = RecoveryManager(kvs).recover_all()
    assert {r.shard for r in reports} <= {0, 1, 2}
    for g in shards:
        assert g.live == (True, True)
        assert dict(g.replicas[0].inner.scan()) == \
            dict(g.replicas[1].inner.scan())
        assert g.pending_repairs(0) == 0 and g.pending_repairs(1) == 0


def test_scan_fails_over_when_preferred_replica_down():
    """scan() is the recovery primitive — it must fail over exactly like
    multiget when the preferred replica is killed but not yet marked down
    (the recovery paths built on scan assume a live preferred replica)."""
    g, reps = _group(n=3)
    g.multiput([("a", b"1"), ("b", b"2")])
    reps[0].kill()                             # stale _live[0] == True
    assert dict(g.scan()) == {"a": b"1", "b": b"2"}
    assert g.live == (False, True, True)       # discovered during the scan
    assert g.stats.n_failovers >= 1


def test_rebuild_source_selection_fails_over_stale_live_survivor():
    """rebuild() picks its survivor by live flags; a candidate killed since
    its last op (flag still True) must be failed over like any read —
    marked down, next peer tried — not crash the rebuild."""
    g, reps = _group(n=3)
    g.multiput([("a", b"1"), ("b", b"2")])
    g.mark_down(0)                             # target: down, then revived
    g.put("late", b"z")                        # logged for replica 0
    reps[1].kill()                             # preferred survivor, stale flag
    rep = RecoveryManager(g).rebuild(0)
    assert rep.source == 2                     # skipped the dead candidate
    assert g.live == (True, False, True)       # 1 discovered down, 0 rebuilt
    assert dict(reps[0].inner.scan()) == dict(reps[2].inner.scan())
    assert g.pending_repairs(0) == 0
    # the discovered-dead survivor is rebuildable afterwards, same path
    reps[1].revive()
    RecoveryManager(g).rebuild(1)
    assert g.live == (True, True, True)
    assert dict(reps[1].inner.scan()) == dict(reps[2].inner.scan())


def test_recover_all_survives_stale_live_replica_during_flush():
    """recover_all's final repair-log flush must not crash on a replica
    whose live flag went stale: mark it down (the log survives — flushes
    drop ops only after they apply) instead of raising ShardDown."""
    g, reps = _group(n=3)
    g.multiput([("a", b"1"), ("b", b"2")])
    g.mark_down(2)
    g.put("late", b"z")                        # repair log for replica 2
    g.mark_live(2)                             # back in rotation, log pending
    reps[2].kill()                             # ...but actually dead
    reports = RecoveryManager(g).recover_all()
    assert reports == []                       # nothing was marked down going in
    assert g.live == (True, True, False)       # discovered during the flush
    assert g.pending_repairs(2) == 1           # log kept for the next rebuild
    reps[2].revive()
    RecoveryManager(g).recover_all()
    assert g.live == (True, True, True)
    assert g.pending_repairs(2) == 0
    assert dict(reps[2].inner.scan()) == dict(reps[0].inner.scan())


# ----------------------------------------------------------- RStore on top
def _replicated_store(n_shards=3, R=2, quorum=1, **cfg_kw):
    groups = [ReplicatedKVS([FaultInjectingKVS(InMemoryKVS(), seed=i * R + r)
                             for r in range(R)], write_quorum=quorum)
              for i in range(n_shards)]
    kvs = ShardedKVS(groups)
    cfg = RStoreConfig(algorithm="bottom_up", capacity=1024, batch_size=4,
                       **cfg_kw)
    return RStore(cfg, kvs=kvs), kvs, groups


def test_rstore_survives_replica_death_mid_workload():
    rs, kvs, groups = _replicated_store()
    rng = np.random.default_rng(11)

    def pay():
        return rng.integers(0, 256, 64, dtype=np.uint8).tobytes()

    v = rs.init_root({k: pay() for k in range(16)})
    vids = [v]
    for _ in range(9):
        v = rs.commit([v], adds={int(rng.integers(0, 16)): pay()})
        vids.append(v)
    rs.flush()
    snap = rs.snapshot()
    qs = [Q.version(vids[-1]), Q.record(vids[-1], 3),
          Q.range(vids[0], 2, 9), Q.evolution(5)]
    healthy = [r.value for r in snap.execute(qs)]
    rts_healthy = snap.execute(qs).batch.kvs_queries

    for g in groups:
        g.replicas[0].kill()               # one replica death per shard
    res = snap.execute(qs)
    assert [r.value for r in res] == healthy
    # router-level round trips unchanged: the failover is absorbed inside
    # each group (≤1 extra inner attempt, counted on group stats)
    assert res.batch.kvs_queries == rts_healthy
    assert all(g.stats.n_failovers <= 1 for g in groups)

    # the write path keeps working degraded (quorum 1 of 2), unchanged
    with rs.writer() as w:
        v2 = w.commit([vids[-1]], adds={3: pay()})
    got, _ = rs.get_record(v2, 3)
    assert got is not None


def test_rstore_compaction_gc_spans_replicas_and_recovery_preserves_it():
    rs, kvs, groups = _replicated_store()
    rng = np.random.default_rng(13)

    def pay():
        return rng.integers(0, 256, 96, dtype=np.uint8).tobytes()

    v = rs.init_root({k: pay() for k in range(12)})
    vids = [v]
    for _ in range(14):
        v = rs.commit([v], adds={int(rng.integers(0, 12)): pay()})
        vids.append(v)
    rs.flush()
    for g in groups:
        g.replicas[0].kill()               # compact while degraded
    rs.retain(keep_last(6))
    rep = rs.compact()
    assert rep.mode == "pass"
    live = [x for x in vids if not rs.graph.is_retired(x)]
    oracle = {}
    for x in live:
        oracle[x] = rs.get_version(x)[0]

    for g in groups:
        g.replicas[0].revive()
    RecoveryManager(kvs).recover_all()
    for g in groups:                       # GC propagated: no resurrected keys
        assert dict(g.replicas[0].inner.scan()) == \
            dict(g.replicas[1].inner.scan())
    for g in groups:
        g.replicas[1].kill()               # read everything off rebuilt side
    for x in live:
        assert rs.get_version(x)[0] == oracle[x]


def test_rstore_flaky_replicas_masked_by_retries():
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=50 + i * 3 + r,
                           p_transient=0.3, p_timeout=0.2)
         for r in range(3)], write_quorum=2) for i in range(2)]
    kvs = ShardedKVS(groups)
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=512,
                             batch_size=3), kvs=kvs)
    oracle_rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=512,
                                    batch_size=3), kvs=InMemoryKVS())
    rng1, rng2 = np.random.default_rng(21), np.random.default_rng(21)

    def drive(store, rng):
        def pay():
            return rng.integers(0, 256, 48, dtype=np.uint8).tobytes()
        v = store.init_root({k: pay() for k in range(10)})
        vids = [v]
        for _ in range(8):
            v = store.commit([v], adds={int(rng.integers(0, 12)): pay()})
            vids.append(v)
        return [store.get_version(x)[0] for x in vids]

    assert drive(rs, rng1) == drive(oracle_rs, rng2)
    merged = KVSStats.merged([g.stats for g in groups])
    assert merged.n_retries > 0
    assert merged.simulated_backoff_seconds > 0


# ------------------------------------------------------------- launch wiring
def test_make_sharded_backend_replication_factor():
    from repro.core.kvs import ShardedDeviceKVS
    from repro.launch.mesh import make_sharded_backend

    kvs = make_sharded_backend(n_shards=2, replication_factor=2)
    assert len(kvs.shards) == 2
    for g in kvs.shards:
        assert isinstance(g, ReplicatedKVS)
        assert len(g.replicas) == 2
        assert all(isinstance(r, ShardedDeviceKVS) for r in g.replicas)
    kvs.multiput([(f"k{i}", bytes([i]) * 8) for i in range(6)])
    assert kvs.multiget(["k1", "k4"]) == [b"\x01" * 8, b"\x04" * 8]
    for g in kvs.shards:                       # every replica has its copy
        for r in g.replicas:
            assert r.total_stored_bytes() > 0
    # R=1 keeps the plain un-replicated router (back-compat)
    plain = make_sharded_backend(n_shards=2, replication_factor=1)
    assert all(isinstance(s, ShardedDeviceKVS) for s in plain.shards)
