"""Property-based end-to-end test: for ANY random commit workload (branched
parents, random add/modify/delete mixes, random batch sizes and algorithms),
every query class returns exactly what the version-graph oracle says."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import RStore, RStoreConfig
from repro.core.kvs import InMemoryKVS, ShardedKVS


@st.composite
def workload(draw):
    n_commits = draw(st.integers(2, 8))
    ops = []
    for _ in range(n_commits):
        ops.append({
            "parent_choice": draw(st.integers(0, 10**6)),
            "second_parent": draw(st.booleans()),
            "mods": draw(st.lists(st.integers(0, 24), min_size=0, max_size=4)),
            "inserts": draw(st.lists(st.integers(25, 40), min_size=0,
                                     max_size=3)),
            "dels": draw(st.lists(st.integers(0, 24), min_size=0, max_size=2)),
        })
    return {
        "algorithm": draw(st.sampled_from(["bottom_up", "depth_first",
                                           "shingle"])),
        "k": draw(st.sampled_from([1, 3])),
        "batch": draw(st.integers(1, 6)),
        "capacity": draw(st.sampled_from([256, 1024, 4096])),
        # backend: single in-memory store or the hash-sharded router —
        # results must be identical either way
        "n_shards": draw(st.sampled_from([0, 2, 4])),
        "ops": ops,
        "seed": draw(st.integers(0, 2**31 - 1)),
    }


@given(workload())
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_random_workload_queries_exact(w):
    rng = np.random.default_rng(w["seed"])

    def pay():
        return rng.integers(0, 256, int(rng.integers(16, 96)),
                            dtype=np.uint8).tobytes()

    kvs = (InMemoryKVS() if w["n_shards"] == 0 else
           ShardedKVS([InMemoryKVS() for _ in range(w["n_shards"])]))
    rs = RStore(RStoreConfig(algorithm=w["algorithm"], capacity=w["capacity"],
                             k=w["k"], batch_size=w["batch"]), kvs=kvs)
    vids = [rs.init_root({pk: pay() for pk in range(12)})]

    for op in w["ops"]:
        parent = vids[op["parent_choice"] % len(vids)]
        pmap_keys = set(
            rs.graph.store.keys()[rs.graph.members(parent)].tolist())
        adds = {pk: pay() for pk in set(op["mods"]) | set(op["inserts"])}
        dels = [pk for pk in set(op["dels"])
                if pk in pmap_keys and pk not in adds]
        parents = [parent]
        if op["second_parent"] and len(vids) > 1:
            other = vids[(op["parent_choice"] // 7) % len(vids)]
            if other != parent:
                parents.append(other)
        vids.append(rs.commit(parents, adds=adds, dels=dels))

    keys_arr = rs.graph.store.keys()

    # Q1 everywhere
    for v in vids:
        got, _ = rs.get_version(v)
        m = rs.graph.members(v)
        want = {int(keys_arr[r]): rs.graph.store.payload(int(r)) for r in m}
        assert got == want

    # Q-point / Q2 / Q3 on the last version
    v = vids[-1]
    m = rs.graph.members(v)
    live = {int(keys_arr[r]): int(r) for r in m}
    for pk in list(live)[:3]:
        got, _ = rs.get_record(v, pk)
        assert got == rs.graph.store.payload(live[pk])
    got, _ = rs.get_record(v, 10_000)
    assert got is None
    rng_got, _ = rs.get_range(v, 5, 15)
    assert rng_got == {pk: rs.graph.store.payload(r)
                       for pk, r in live.items() if 5 <= pk <= 15}
    some_key = next(iter(live)) if live else 0
    evo, _ = rs.get_evolution(some_key)
    origins = [o for o, _ in evo]
    want_origins = sorted(
        {int(rs.graph.store.origin_versions()[r])
         for r in range(len(rs.graph.store))
         if int(keys_arr[r]) == some_key},
        key=lambda x: rs.graph.versions.index(x))
    assert origins == want_origins
