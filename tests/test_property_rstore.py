"""Property-based end-to-end test: for ANY random commit workload (branched
parents, random add/modify/delete mixes, random batch sizes and algorithms),
every query class returns exactly what the version-graph oracle says — and
for ANY interleaving of commits, retention pruning, and compaction passes,
retained versions stay byte-identical and the KVS holds no orphaned keys."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import CachingKVS, Q, RStore, RStoreConfig, keep_last
from repro.core.kvs import InMemoryKVS, ShardedKVS
from repro.core.replica import (FaultInjectingKVS, RecoveryManager,
                                ReplicatedKVS)


@st.composite
def workload(draw):
    n_commits = draw(st.integers(2, 8))
    ops = []
    for _ in range(n_commits):
        ops.append({
            "parent_choice": draw(st.integers(0, 10**6)),
            "second_parent": draw(st.booleans()),
            "mods": draw(st.lists(st.integers(0, 24), min_size=0, max_size=4)),
            "inserts": draw(st.lists(st.integers(25, 40), min_size=0,
                                     max_size=3)),
            "dels": draw(st.lists(st.integers(0, 24), min_size=0, max_size=2)),
        })
    return {
        "algorithm": draw(st.sampled_from(["bottom_up", "depth_first",
                                           "shingle"])),
        "k": draw(st.sampled_from([1, 3])),
        "batch": draw(st.integers(1, 6)),
        "capacity": draw(st.sampled_from([256, 1024, 4096])),
        # backend: single in-memory store or the hash-sharded router —
        # results must be identical either way
        "n_shards": draw(st.sampled_from([0, 2, 4])),
        "ops": ops,
        "seed": draw(st.integers(0, 2**31 - 1)),
    }


@given(workload())
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_random_workload_queries_exact(w):
    rng = np.random.default_rng(w["seed"])

    def pay():
        return rng.integers(0, 256, int(rng.integers(16, 96)),
                            dtype=np.uint8).tobytes()

    kvs = (InMemoryKVS() if w["n_shards"] == 0 else
           ShardedKVS([InMemoryKVS() for _ in range(w["n_shards"])]))
    rs = RStore(RStoreConfig(algorithm=w["algorithm"], capacity=w["capacity"],
                             k=w["k"], batch_size=w["batch"]), kvs=kvs)
    vids = [rs.init_root({pk: pay() for pk in range(12)})]

    for op in w["ops"]:
        parent = vids[op["parent_choice"] % len(vids)]
        pmap_keys = set(
            rs.graph.store.keys()[rs.graph.members(parent)].tolist())
        adds = {pk: pay() for pk in set(op["mods"]) | set(op["inserts"])}
        dels = [pk for pk in set(op["dels"])
                if pk in pmap_keys and pk not in adds]
        parents = [parent]
        if op["second_parent"] and len(vids) > 1:
            other = vids[(op["parent_choice"] // 7) % len(vids)]
            if other != parent:
                parents.append(other)
        vids.append(rs.commit(parents, adds=adds, dels=dels))

    keys_arr = rs.graph.store.keys()

    # Q1 everywhere
    for v in vids:
        got, _ = rs.get_version(v)
        m = rs.graph.members(v)
        want = {int(keys_arr[r]): rs.graph.store.payload(int(r)) for r in m}
        assert got == want

    # Q-point / Q2 / Q3 on the last version
    v = vids[-1]
    m = rs.graph.members(v)
    live = {int(keys_arr[r]): int(r) for r in m}
    for pk in list(live)[:3]:
        got, _ = rs.get_record(v, pk)
        assert got == rs.graph.store.payload(live[pk])
    got, _ = rs.get_record(v, 10_000)
    assert got is None
    rng_got, _ = rs.get_range(v, 5, 15)
    assert rng_got == {pk: rs.graph.store.payload(r)
                       for pk, r in live.items() if 5 <= pk <= 15}
    some_key = next(iter(live)) if live else 0
    evo, _ = rs.get_evolution(some_key)
    origins = [o for o, _ in evo]
    want_origins = sorted(
        {int(rs.graph.store.origin_versions()[r])
         for r in range(len(rs.graph.store))
         if int(keys_arr[r]) == some_key},
        key=lambda x: rs.graph.versions.index(x))
    assert origins == want_origins


# ---------------------------------------------------- compaction & retention
@st.composite
def maintenance_workload(draw):
    """Interleaved streams of commit waves, retention prunings, and
    compaction passes."""
    steps = []
    for _ in range(draw(st.integers(2, 6))):
        kind = draw(st.sampled_from(["commits", "commits", "retain",
                                     "compact"]))
        if kind == "commits":
            steps.append(("commits", draw(st.integers(1, 6))))
        elif kind == "retain":
            steps.append(("retain", draw(st.integers(1, 8))))
        else:
            steps.append(("compact", draw(st.floats(0.3, 1.0))))
    return {
        "algorithm": draw(st.sampled_from(["bottom_up", "depth_first",
                                           "shingle"])),
        "k": draw(st.sampled_from([1, 1, 3])),
        "batch": draw(st.integers(1, 6)),
        "capacity": draw(st.sampled_from([512, 2048])),
        "n_shards": draw(st.sampled_from([0, 3])),
        "steps": steps,
        "seed": draw(st.integers(0, 2**31 - 1)),
    }


def _all_kvs_keys(kvs):
    if isinstance(kvs, ShardedKVS):
        out = set()
        for s in kvs.shards:
            out |= set(s._d)
        return out
    return set(kvs._d)


@given(maintenance_workload())
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_retention_compaction_interleavings_exact(w):
    """After ANY interleaving of commits, retention prunings, and compaction
    passes: (a) every retained version reconstructs byte-identically to its
    pre-maintenance content, and (b) no KVS key is orphaned — the stored key
    set is exactly {chunk/i, map/i} for the chunk ids the index references."""
    rng = np.random.default_rng(w["seed"])

    def pay():
        return rng.integers(0, 256, int(rng.integers(16, 96)),
                            dtype=np.uint8).tobytes()

    kvs = (InMemoryKVS() if w["n_shards"] == 0 else
           ShardedKVS([InMemoryKVS() for _ in range(w["n_shards"])]))
    rs = RStore(RStoreConfig(algorithm=w["algorithm"], capacity=w["capacity"],
                             k=w["k"], batch_size=w["batch"]), kvs=kvs)
    v = rs.init_root({pk: pay() for pk in range(10)})
    vids = [v]
    # oracle: payload map of every version at commit time (immutable)
    oracle = {}

    def snap_oracle(vid):
        m = rs.graph.members(vid)
        ks = rs.graph.store.keys()[m]
        oracle[vid] = {int(k): rs.graph.store.payload(int(r))
                       for k, r in zip(ks, m)}

    snap_oracle(v)
    for kind, arg in w["steps"]:
        if kind == "commits":
            for _ in range(arg):
                parent = vids[-1]
                adds = {int(rng.integers(0, 10)): pay()}
                if rng.integers(0, 2):
                    adds[10 + int(rng.integers(0, 20))] = pay()
                v = rs.commit([parent], adds=adds)
                vids.append(v)
                snap_oracle(v)
        elif kind == "retain":
            retired = rs.retain(keep_last(arg))
            vids = [x for x in vids if x not in set(retired)]
        else:
            rs.compact(liveness_threshold=arg)
        rs.graph.check_invariants()

    rs.flush()
    keys_arr = rs.graph.store.keys()
    # (a) every retained version is byte-identical to its commit-time content
    for vid in vids:
        got, _ = rs.get_version(vid)
        assert got == oracle[vid], f"version {vid} diverged"
    # (b) no orphaned (or missing) KVS keys
    want = set()
    for cid in rs._chunk_records:
        want |= {f"chunk/{cid}", f"map/{cid}"}
    assert _all_kvs_keys(kvs) == want
    # evolution of any key returns only records live in a retained version
    live_rids = set()
    for vid in vids:
        live_rids |= set(rs.graph.members(vid).tolist())
    pk = int(next(iter(oracle[vids[-1]])))
    evo, _ = rs.get_evolution(pk)
    stored_rids = {int(r) for rids in rs._chunk_records.values() for r in rids}
    want_evo = sorted(
        {int(rs.graph.store.origin_versions()[r])
         for r in stored_rids & live_rids if int(keys_arr[r]) == pk},
        key=lambda x: rs.graph.versions.index(x))
    assert [o for o, _ in evo] == want_evo


# ------------------------------------------------- replication under faults
@st.composite
def fault_plan(draw):
    """A replicated backend shape plus a random fault schedule: per-op
    transient/timeout probabilities and optionally one hard replica kill
    partway through the workload."""
    return {
        "R": draw(st.sampled_from([2, 3])),
        "n_shards": draw(st.sampled_from([1, 3])),
        "p_transient": draw(st.sampled_from([0.0, 0.15, 0.3])),
        "p_timeout": draw(st.sampled_from([0.0, 0.15])),
        "kill": draw(st.booleans()),
        "kill_step": draw(st.integers(0, 5)),
        "seed": draw(st.integers(0, 2**31 - 1)),
    }


def _run_steps(rs, rng, steps, on_step, probe=None):
    """Drive the maintenance-workload step stream against ``rs``; call
    ``on_step(i)`` before each step (fault-schedule hook) and ``probe(vids)``
    after each step (mid-run read hook — both runs of a comparison must pass
    the same probe shape so their flush timing stays identical)."""
    v = rs.init_root({pk: rng.integers(0, 256, int(rng.integers(16, 96)),
                                       dtype=np.uint8).tobytes()
                      for pk in range(10)})
    vids = [v]
    for i, (kind, arg) in enumerate(steps):
        on_step(i)
        if kind == "commits":
            for _ in range(arg):
                adds = {int(rng.integers(0, 10)): rng.integers(
                    0, 256, int(rng.integers(16, 96)),
                    dtype=np.uint8).tobytes()}
                if rng.integers(0, 2):
                    adds[10 + int(rng.integers(0, 20))] = rng.integers(
                        0, 256, int(rng.integers(16, 96)),
                        dtype=np.uint8).tobytes()
                vids.append(rs.commit([vids[-1]], adds=adds))
        elif kind == "retain":
            retired = set(rs.retain(keep_last(arg)))
            vids = [x for x in vids if x not in retired]
        else:
            rs.compact(liveness_threshold=arg)
        if probe is not None:
            probe(vids)
    rs.flush()
    return vids


def _check_replicated_faulty(w, fp):
    """Body of test_replicated_faulty_backend_byte_identical, callable with
    concrete (workload, fault-plan) dicts — also exercised by
    test_replicated_faulty_fixed_examples below when hypothesis is absent."""
    cfg = dict(algorithm=w["algorithm"], capacity=w["capacity"], k=w["k"],
               batch_size=w["batch"])
    R, n_shards = fp["R"], fp["n_shards"]

    rs0 = RStore(RStoreConfig(**cfg), kvs=InMemoryKVS())
    vids0 = _run_steps(rs0, np.random.default_rng(w["seed"]), w["steps"],
                       lambda i: None)

    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=fp["seed"] + i * R + r,
                           p_transient=fp["p_transient"],
                           p_timeout=fp["p_timeout"])
         for r in range(R)], write_quorum=1) for i in range(n_shards)]
    kvs1 = groups[0] if n_shards == 1 else ShardedKVS(groups)
    rs1 = RStore(RStoreConfig(**cfg), kvs=kvs1)
    kill_at = fp["kill_step"] % len(w["steps"]) if fp["kill"] else None

    def on_step(i):
        if i == kill_at:
            for g in groups:
                g.replicas[0].kill()

    vids1 = _run_steps(rs1, np.random.default_rng(w["seed"]), w["steps"],
                       on_step)

    # identical interleaving → identical retained versions, byte-identical
    # content for every query class
    assert vids1 == vids0
    for vid in vids0:
        assert rs1.get_version(vid)[0] == rs0.get_version(vid)[0]
    v = vids0[-1]
    pk = next(iter(rs0.get_version(v)[0]))
    assert rs1.get_record(v, pk)[0] == rs0.get_record(v, pk)[0]
    assert rs1.get_range(v, 0, 15)[0] == rs0.get_range(v, 0, 15)[0]
    assert rs1.get_evolution(pk)[0] == rs0.get_evolution(pk)[0]

    # recovery: revive the killed replicas, rebuild, and require every
    # replica of every group to converge byte-identically with an empty
    # repair log (missed GC deletes must not resurrect chunks)
    if kill_at is not None:
        for g in groups:
            g.replicas[0].revive()
    RecoveryManager(kvs1).recover_all()
    for g in groups:
        want = dict(g.replicas[0].inner.scan())
        for idx, r in enumerate(g.replicas):
            assert dict(r.inner.scan()) == want
            assert g.pending_repairs(idx) == 0
    # the replicated run stores exactly the same logical key set as the
    # fault-free run
    assert set().union(*(dict(g.replicas[0].inner.scan())
                         for g in groups)) == set(rs0.kvs._d)


@given(maintenance_workload(), fault_plan())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_replicated_faulty_backend_byte_identical(w, fp):
    """The SAME commit/retain/compact interleaving, run once on a plain
    in-memory backend and once on a replicated backend with a random fault
    schedule (injected transients/timeouts, optionally one replica of every
    group hard-killed mid-run), must return byte-identical results for every
    query — and after revive + recover_all every replica converges to the
    same key/value set with empty repair logs."""
    _check_replicated_faulty(w, fp)


# fixed corner examples so the contract is still exercised when hypothesis
# is unavailable (conftest shims @given into a skip)
_FAULT_EXAMPLES = [
    # flaky replicas, no kill, single replicated shard
    ({"algorithm": "bottom_up", "k": 1, "batch": 3, "capacity": 512,
      "n_shards": 0, "seed": 7,
      "steps": [("commits", 4), ("retain", 3), ("commits", 3),
                ("compact", 0.6)]},
     {"R": 2, "n_shards": 1, "p_transient": 0.3, "p_timeout": 0.15,
      "kill": False, "kill_step": 0, "seed": 11}),
    # hard kill before the compact step, sharded router, R=3
    ({"algorithm": "shingle", "k": 3, "batch": 2, "capacity": 2048,
      "n_shards": 0, "seed": 19,
      "steps": [("commits", 5), ("retain", 4), ("compact", 1.0),
                ("commits", 2)]},
     {"R": 3, "n_shards": 3, "p_transient": 0.15, "p_timeout": 0.0,
      "kill": True, "kill_step": 2, "seed": 23}),
    # kill at step 0: the whole workload runs degraded
    ({"algorithm": "depth_first", "k": 1, "batch": 4, "capacity": 512,
      "n_shards": 0, "seed": 31,
      "steps": [("commits", 3), ("compact", 0.4), ("retain", 2),
                ("commits", 2)]},
     {"R": 2, "n_shards": 3, "p_transient": 0.0, "p_timeout": 0.15,
      "kill": True, "kill_step": 0, "seed": 37}),
]


@pytest.mark.parametrize("w,fp", _FAULT_EXAMPLES,
                         ids=["flaky", "kill-mid", "kill-start"])
def test_replicated_faulty_fixed_examples(w, fp):
    _check_replicated_faulty(w, fp)


# ------------------------------------------------------ chunk cache coherence
@st.composite
def cache_plan(draw):
    """CachingKVS shapes: budgets from eviction-churn-tiny to everything-fits,
    with and without the tiny-blob admission bypass."""
    return {
        "cache_bytes": draw(st.sampled_from([1 << 12, 1 << 16, 4 << 20])),
        "always_admit_bytes": draw(st.sampled_from([0, 4096])),
    }


def _check_cached_coherent(w, fp, cp):
    """Body of test_cached_reads_byte_identical_under_interleavings, callable
    with concrete (workload, fault-plan, cache-plan) dicts — also exercised
    by test_cached_coherence_fixed_examples when hypothesis is absent."""
    cfg = dict(algorithm=w["algorithm"], capacity=w["capacity"], k=w["k"],
               batch_size=w["batch"])
    R, n_shards = fp["R"], fp["n_shards"]

    # oracle: plain uncached in-memory backend, probed after every step
    probes0 = []
    rs0 = RStore(RStoreConfig(**cfg), kvs=InMemoryKVS())

    def probe0(vids):
        got, _ = rs0.get_version(vids[-1])
        pk = next(iter(got)) if got else 0
        probes0.append((got, rs0.get_evolution(pk)[0]))

    vids0 = _run_steps(rs0, np.random.default_rng(w["seed"]), w["steps"],
                       lambda i: None, probe=probe0)

    # subject: CachingKVS over a replicated (optionally sharded, optionally
    # faulty/killed) backend, same interleaving, same probes
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=fp["seed"] + i * R + r,
                           p_transient=fp["p_transient"],
                           p_timeout=fp["p_timeout"])
         for r in range(R)], write_quorum=1) for i in range(n_shards)]
    kvs1 = CachingKVS(groups[0] if n_shards == 1 else ShardedKVS(groups),
                      cache_bytes=cp["cache_bytes"],
                      always_admit_bytes=cp["always_admit_bytes"])
    rs1 = RStore(RStoreConfig(**cfg), kvs=kvs1)
    kill_at = fp["kill_step"] % len(w["steps"]) if fp["kill"] else None
    probes1 = []

    def on_step(i):
        if i == kill_at:
            for g in groups:
                g.replicas[0].kill()

    def probe1(vids):
        got, _ = rs1.get_version(vids[-1])
        pk = next(iter(got)) if got else 0
        probes1.append((got, rs1.get_evolution(pk)[0]))
        # the byte budget is an invariant, not a steady-state property
        assert kvs1.cached_bytes <= kvs1.cache_bytes

    vids1 = _run_steps(rs1, np.random.default_rng(w["seed"]), w["steps"],
                       on_step, probe=probe1)

    # identical interleaving → identical version ids, and every mid-run
    # probe through the cache was byte-identical to the uncached oracle
    assert vids1 == vids0
    assert probes1 == probes0
    # final state: every retained version + every query class byte-identical
    for vid in vids0:
        assert rs1.get_version(vid)[0] == rs0.get_version(vid)[0]
    v = vids0[-1]
    pk = next(iter(rs0.get_version(v)[0]))
    assert rs1.get_record(v, pk)[0] == rs0.get_record(v, pk)[0]
    assert rs1.get_range(v, 0, 15)[0] == rs0.get_range(v, 0, 15)[0]
    assert rs1.get_evolution(pk)[0] == rs0.get_evolution(pk)[0]
    # the cache was actually exercised, and the budget still holds
    assert kvs1.stats.n_cache_hits + kvs1.stats.n_cache_misses > 0
    assert kvs1.cached_bytes <= kvs1.cache_bytes


@given(maintenance_workload(), fault_plan(), cache_plan())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_cached_reads_byte_identical_under_interleavings(w, fp, cp):
    """For ANY interleaving of commit waves, retention prunings, compaction
    passes, and replica kills, reads through a CachingKVS (any budget, any
    admission tuning) are byte-identical to an uncached oracle run — both
    mid-run after every step and at the end for every query class — and the
    cache never exceeds its byte budget."""
    _check_cached_coherent(w, fp, cp)


# fixed corner examples so the coherence contract is still exercised when
# hypothesis is unavailable (conftest shims @given into a skip)
_CACHE_EXAMPLES = [
    # tiny budget: constant eviction/admission churn across a compact pass
    ({"algorithm": "bottom_up", "k": 1, "batch": 3, "capacity": 512,
      "n_shards": 0, "seed": 43,
      "steps": [("commits", 4), ("compact", 0.6), ("commits", 3),
                ("retain", 3), ("compact", 1.0)]},
     {"R": 2, "n_shards": 1, "p_transient": 0.0, "p_timeout": 0.0,
      "kill": False, "kill_step": 0, "seed": 47},
     {"cache_bytes": 1 << 12, "always_admit_bytes": 0}),
    # big budget, flaky sharded replicas, kill mid-run: warm cache must stay
    # coherent through failover + retention + compaction
    ({"algorithm": "shingle", "k": 1, "batch": 2, "capacity": 2048,
      "n_shards": 0, "seed": 53,
      "steps": [("commits", 5), ("retain", 4), ("compact", 0.8),
                ("commits", 2)]},
     {"R": 2, "n_shards": 3, "p_transient": 0.15, "p_timeout": 0.15,
      "kill": True, "kill_step": 1, "seed": 59},
     {"cache_bytes": 4 << 20, "always_admit_bytes": 4096}),
    # k>1: compaction falls back to a full rebuild — the layout-epoch hook
    # (not incremental invalidation) carries the coherence load
    ({"algorithm": "depth_first", "k": 3, "batch": 4, "capacity": 1024,
      "n_shards": 0, "seed": 61,
      "steps": [("commits", 4), ("compact", 0.5), ("retain", 2),
                ("commits", 2), ("compact", 1.0)]},
     {"R": 3, "n_shards": 1, "p_transient": 0.0, "p_timeout": 0.15,
      "kill": True, "kill_step": 0, "seed": 67},
     {"cache_bytes": 1 << 16, "always_admit_bytes": 4096}),
]


@pytest.mark.parametrize("w,fp,cp", _CACHE_EXAMPLES,
                         ids=["tiny-budget", "kill-warm", "k3-rebuild"])
def test_cached_coherence_fixed_examples(w, fp, cp):
    _check_cached_coherent(w, fp, cp)


# --------------------------------------------- secondary index coherence
def _tag_extractor(payload: bytes) -> dict:
    # low cardinality (4 values) so postings stay dense across random payloads
    return {"tag": payload[0] % 4}


def _check_secondary_coherent(w, fp):
    """Body of test_secondary_index_byte_identical_under_interleavings,
    callable with concrete (workload, fault-plan) dicts — also exercised by
    test_secondary_fixed_examples when hypothesis is absent."""
    cfg = dict(algorithm=w["algorithm"], capacity=w["capacity"], k=w["k"],
               batch_size=w["batch"])
    R, n_shards = fp["R"], fp["n_shards"]

    # oracle: plain in-memory, UNINDEXED store — every Q.where answer is
    # checked against a brute-force full-version scan + exact filter here
    probes0 = []
    rs0 = RStore(RStoreConfig(**cfg), kvs=InMemoryKVS())

    def probe0(vids):
        full, _ = rs0.get_version(vids[-1])
        probes0.append([{pk: p for pk, p in full.items()
                         if _tag_extractor(p)["tag"] == t}
                        for t in range(4)])

    vids0 = _run_steps(rs0, np.random.default_rng(w["seed"]), w["steps"],
                       lambda i: None, probe=probe0)

    # subject: indexed store over a replicated (optionally sharded,
    # optionally faulty/killed) backend, same interleaving, same probes —
    # but answered through the secondary index
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=fp["seed"] + i * R + r,
                           p_transient=fp["p_transient"],
                           p_timeout=fp["p_timeout"])
         for r in range(R)], write_quorum=1) for i in range(n_shards)]
    kvs1 = groups[0] if n_shards == 1 else ShardedKVS(groups)
    rs1 = RStore(RStoreConfig(**cfg), kvs=kvs1)
    rs1.create_index("tag", _tag_extractor, n_buckets=3)
    kill_at = fp["kill_step"] % len(w["steps"]) if fp["kill"] else None
    probes1 = []

    def on_step(i):
        if i == kill_at:
            for g in groups:
                g.replicas[0].kill()

    def probe1(vids):
        res = rs1.snapshot().execute(
            [Q.where(vids[-1], "tag", t) for t in range(4)])
        probes1.append([r.value for r in res])

    vids1 = _run_steps(rs1, np.random.default_rng(w["seed"]), w["steps"],
                       on_step, probe=probe1)

    # identical interleaving → identical version ids, and every mid-run
    # filtered scan was byte-identical to the brute-force oracle
    assert vids1 == vids0
    assert probes1 == probes0

    # final sweep: where + where_range on the newest retained version
    snap = rs1.snapshot()
    full, _ = rs0.get_version(vids0[-1])
    for t in range(4):
        got = snap.execute([Q.where(vids0[-1], "tag", t)])[0].value
        assert got == {pk: p for pk, p in full.items()
                       if _tag_extractor(p)["tag"] == t}
    got = snap.execute([Q.where_range(vids0[-1], "tag", 1, 2)])[0].value
    assert got == {pk: p for pk, p in full.items()
                   if 1 <= _tag_extractor(p)["tag"] <= 2}

    # after one more compaction pass: zero orphaned idx2/ keys — the
    # backend's idx2/ key set is exactly the index's live bucket set, and
    # every posting references a stored chunk
    rs1.compact(liveness_threshold=1.0)
    idx = rs1._indexes["tag"]
    stored_idx_keys = {k for k, _ in kvs1.scan() if k.startswith("idx2/")}
    assert stored_idx_keys == set(idx.stored_keys())
    live_cids = set(rs1._chunk_records)
    for postings in idx.postings.values():
        assert set(postings.tolist()) <= live_cids


@given(maintenance_workload(), fault_plan())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_secondary_index_byte_identical_under_interleavings(w, fp):
    """For ANY interleaving of commit waves, retention prunings, compaction
    passes, and replica kills, `Q.where` through a secondary index is
    byte-identical to a brute-force full-scan oracle — mid-run after every
    step and at the end (where + where_range) — and a compaction pass leaves
    zero orphaned idx2/ keys in the backend."""
    _check_secondary_coherent(w, fp)


# fixed corner examples so the contract is still exercised when hypothesis
# is unavailable (conftest shims @given into a skip)
_SECONDARY_EXAMPLES = [
    # retention + two compact passes on a replicated shard: postings must
    # shed retired chunks without orphaning buckets
    ({"algorithm": "bottom_up", "k": 1, "batch": 3, "capacity": 512,
      "n_shards": 0, "seed": 71,
      "steps": [("commits", 4), ("compact", 0.6), ("retain", 3),
                ("commits", 3), ("compact", 1.0)]},
     {"R": 2, "n_shards": 1, "p_transient": 0.15, "p_timeout": 0.0,
      "kill": False, "kill_step": 0, "seed": 73}),
    # k>1 (index maintenance rides the full-rebuild path) + replica kill
    # mid-run on a sharded router
    ({"algorithm": "shingle", "k": 3, "batch": 2, "capacity": 2048,
      "n_shards": 0, "seed": 79,
      "steps": [("commits", 5), ("retain", 4), ("compact", 1.0),
                ("commits", 2)]},
     {"R": 3, "n_shards": 3, "p_transient": 0.0, "p_timeout": 0.15,
      "kill": True, "kill_step": 1, "seed": 83}),
]


@pytest.mark.parametrize("w,fp", _SECONDARY_EXAMPLES,
                         ids=["retain-compact", "k3-kill"])
def test_secondary_fixed_examples(w, fp):
    _check_secondary_coherent(w, fp)


# --------------------------------------------- async ingest interleavings
@st.composite
def async_schedule(draw):
    """Random stage/drain/read/retain/compact/kill schedules driven through
    a BackgroundFlusher on replicated flaky backends."""
    steps = []
    for _ in range(draw(st.integers(3, 8))):
        kind = draw(st.sampled_from(["stage", "stage", "stage", "drain",
                                     "read", "retain", "compact", "kill"]))
        if kind == "stage":
            steps.append(("stage", draw(st.integers(1, 4))))
        elif kind == "retain":
            steps.append(("retain", draw(st.integers(2, 8))))
        elif kind == "compact":
            steps.append(("compact", draw(st.floats(0.3, 1.0))))
        else:
            steps.append((kind, 0))
    return {
        "algorithm": draw(st.sampled_from(["bottom_up", "depth_first"])),
        "capacity": draw(st.sampled_from([512, 2048])),
        "watermark": draw(st.sampled_from([2, 4, 10**9])),
        "n_sessions": draw(st.sampled_from([1, 2, 3])),
        "R": draw(st.sampled_from([2, 3])),
        "n_shards": draw(st.sampled_from([1, 3])),
        "p_transient": draw(st.sampled_from([0.0, 0.2])),
        "p_timeout": draw(st.sampled_from([0.0, 0.15])),
        "steps": steps,
        "seed": draw(st.integers(0, 2**31 - 1)),
    }


def _drive_async_schedule(rs, rng, plan, on_step=lambda i: None):
    """Drive one stage/drain/read/retain/compact/kill schedule against
    ``rs``.  With a flusher attached, stages go through ``n_sessions``
    concurrent WriteSessions round-robin; without one (the synchronous-
    flush oracle) the same flat commit sequence goes through the facade
    with a flush at every drain point.  Identical op order -> identical
    version ids, so the two runs are directly comparable."""
    is_async = rs.flusher is not None
    n_sessions = plan["n_sessions"]
    watermark = plan["watermark"]

    def pay():
        return rng.integers(0, 256, int(rng.integers(16, 96)),
                            dtype=np.uint8).tobytes()

    records = {pk: pay() for pk in range(10)}
    if is_async:
        with rs.writer() as boot:
            root = boot.init_root(records)
        sessions = [rs.writer() for _ in range(n_sessions)]
    else:
        root = rs.init_root(records)
        sessions = None
    heads = [root] * n_sessions
    vids, reads, turn = [root], [], 0
    # lag model: version-watermark drains fire deterministically, so the
    # flusher's staged count is exactly predictable step by step
    expected_staged = 1 if is_async else None
    if is_async and expected_staged >= watermark:
        expected_staged = 0

    for i, (kind, arg) in enumerate(plan["steps"]):
        on_step(i)
        if kind == "stage":
            for _ in range(arg):
                j = turn % n_sessions
                turn += 1
                adds = {int(rng.integers(0, 10)): pay()}
                if rng.integers(0, 2):
                    adds[10 + int(rng.integers(0, 20))] = pay()
                if is_async:
                    v = sessions[j].commit([heads[j]], adds=adds)
                    expected_staged += 1
                    if expected_staged >= watermark:
                        expected_staged = 0
                    assert rs.flusher.staged_versions == expected_staged
                else:
                    v = rs.commit([heads[j]], adds=adds)
                heads[j] = v
                vids.append(v)
        elif kind == "drain":
            rs.barrier()
            if is_async:
                expected_staged = 0
        elif kind == "read":
            got, _ = rs.get_version(vids[-1])   # fresh snapshot: drains
            reads.append(got)
            if is_async:
                expected_staged = 0
        elif kind == "retain":
            retired = set(rs.retain(keep_last(arg)))
            vids = [x for x in vids if x not in retired]
            heads = [h if h not in retired else vids[-1] for h in heads]
            if is_async:
                expected_staged = 0
        elif kind == "compact":
            rs.compact(liveness_threshold=arg)
            if is_async:
                expected_staged = 0
        # "kill" is a schedule marker: on_step injects it in the subject run
        rs.graph.check_invariants()
    if is_async:
        for s in sessions:
            s.close()
    rs.barrier()
    return vids, reads


def _check_async_interleaving(plan):
    """Body of test_async_ingest_interleavings_byte_identical, callable with
    a concrete schedule dict — also exercised by the fixed examples below
    when hypothesis is absent."""
    from repro.core import RetryPolicy

    cfg = dict(algorithm=plan["algorithm"], capacity=plan["capacity"], k=1,
               batch_size=10**9)
    # oracle: synchronous flush on a plain in-memory backend
    rs0 = RStore(RStoreConfig(**cfg), kvs=InMemoryKVS())
    vids0, reads0 = _drive_async_schedule(
        rs0, np.random.default_rng(plan["seed"]), plan)

    # subject: BackgroundFlusher over replicated flaky (optionally killed)
    # shards.  Per-replica retries inside the group absorb scheduled
    # faults (max_consecutive_faults=2 < max_retries), so drains converge.
    R, n_shards = plan["R"], plan["n_shards"]
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=plan["seed"] + i * R + r,
                           p_transient=plan["p_transient"],
                           p_timeout=plan["p_timeout"])
         for r in range(R)], write_quorum=1) for i in range(n_shards)]
    kvs1 = groups[0] if n_shards == 1 else ShardedKVS(groups)
    rs1 = RStore(RStoreConfig(**cfg), kvs=kvs1)
    rs1.attach_flusher(max_staged_versions=plan["watermark"],
                       retry=RetryPolicy(max_retries=4))
    kill_steps = [i for i, (k, _) in enumerate(plan["steps"]) if k == "kill"]

    def on_step(i):
        if i in kill_steps:
            for g in groups:
                g.replicas[0].kill()

    vids1, reads1 = _drive_async_schedule(
        rs1, np.random.default_rng(plan["seed"]), plan, on_step)

    # identical interleaving -> identical version ids; every mid-run read
    # and every retained version byte-identical to the synchronous oracle
    assert vids1 == vids0
    assert reads1 == reads0
    for vid in vids0:
        assert rs1.get_version(vid)[0] == rs0.get_version(vid)[0]
    v = vids0[-1]
    pk = next(iter(rs0.get_version(v)[0]))
    assert rs1.get_evolution(pk)[0] == rs0.get_evolution(pk)[0]
    assert rs1.get_range(v, 0, 15)[0] == rs0.get_range(v, 0, 15)[0]
    # drained state is fully durable: zero lag, zero replay
    ing = rs1.storage_stats()["ingest"]
    assert ing["staleness_lag"] == 0 and ing["pending_replay_writes"] == 0

    # recovery: zero lost/duplicated versions after recover_all — every
    # replica of every group converges byte-identically with empty repair
    # logs, and every retained version still reads back exactly
    if kill_steps:
        for g in groups:
            g.replicas[0].revive()
    RecoveryManager(kvs1).recover_all()
    for g in groups:
        want = dict(g.replicas[0].inner.scan())
        for idx, r in enumerate(g.replicas):
            assert dict(r.inner.scan()) == want
            assert g.pending_repairs(idx) == 0
    for vid in vids0:
        assert rs1.get_version(vid)[0] == rs0.get_version(vid)[0]


@given(async_schedule())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_async_ingest_interleavings_byte_identical(plan):
    """For ANY interleaving of concurrent-session stages, watermark/explicit
    drains, reads, retention prunings, compaction passes, and replica kills,
    async ingest through a BackgroundFlusher returns byte-identical results
    to the synchronous-flush oracle, its staged-version count follows the
    watermark model exactly, and after revive + recover_all no version is
    lost or duplicated on any replica."""
    _check_async_interleaving(plan)


# fixed corner examples so the contract is still exercised when hypothesis
# is unavailable (conftest shims @given into a skip)
_ASYNC_EXAMPLES = [
    # timeout-mid-drain: heavy ack-lost schedule while watermark drains are
    # in flight — replay idempotence carries the run
    {"algorithm": "bottom_up", "capacity": 512, "watermark": 2,
     "n_sessions": 2, "R": 2, "n_shards": 1,
     "p_transient": 0.0, "p_timeout": 0.3, "seed": 101,
     "steps": [("stage", 3), ("drain", 0), ("stage", 4), ("read", 0),
               ("stage", 2), ("drain", 0)]},
    # kill-between-buffers: one buffer drains healthy, replica 0 of every
    # group dies, the next buffer drains through failover
    {"algorithm": "depth_first", "capacity": 2048, "watermark": 10**9,
     "n_sessions": 3, "R": 2, "n_shards": 3,
     "p_transient": 0.15, "p_timeout": 0.0, "seed": 103,
     "steps": [("stage", 4), ("drain", 0), ("kill", 0), ("stage", 4),
               ("drain", 0), ("read", 0)]},
    # compact-during-stage: compaction (and retention) hit while versions
    # are still staged — the drain barrier must land them first
    {"algorithm": "bottom_up", "capacity": 512, "watermark": 10**9,
     "n_sessions": 2, "R": 3, "n_shards": 1,
     "p_transient": 0.2, "p_timeout": 0.15, "seed": 107,
     "steps": [("stage", 4), ("compact", 0.6), ("stage", 3), ("retain", 4),
               ("stage", 2), ("read", 0), ("compact", 1.0)]},
]


@pytest.mark.parametrize("plan", _ASYNC_EXAMPLES,
                         ids=["timeout-mid-drain", "kill-between-buffers",
                              "compact-during-stage"])
def test_async_ingest_fixed_examples(plan):
    _check_async_interleaving(plan)


# ------------------------------------------ composite planner coherence
def _attr2_extractor(payload: bytes) -> dict:
    # two low-cardinality attrs so composite predicates stay non-vacuous
    # across random payloads
    return {"tag": payload[0] % 4, "hue": payload[1] % 3}


def _composite_probes(full):
    """Brute-force full-scan answers for the composite probe battery."""
    def f(pred):
        return {pk: p for pk, p in full.items() if pred(_attr2_extractor(p))}

    return [
        f(lambda a: a["tag"] == 1 and a["hue"] == 2),            # and_
        f(lambda a: a["tag"] == 0 or a["tag"] == 3),             # or_
        f(lambda a: a["tag"] != 2),                              # not_
        f(lambda a: a["hue"] <= 1 and a["tag"] != 0),            # nested
        sum(1 for p in full.values()
            if _attr2_extractor(p)["tag"] == 1),                 # count
        sorted({_attr2_extractor(p)["hue"] for p in full.values()}),
    ]


def _check_composite_planner_coherent(w, fp):
    """Body of test_composite_plans_byte_identical_under_interleavings,
    callable with concrete (workload, fault-plan) dicts — also exercised by
    test_composite_planner_fixed_examples when hypothesis is absent."""
    cfg = dict(algorithm=w["algorithm"], capacity=w["capacity"], k=w["k"],
               batch_size=w["batch"])
    R, n_shards = fp["R"], fp["n_shards"]

    # oracle: plain in-memory, UNINDEXED store — every composite answer is
    # checked against a brute-force full-version scan + exact filter
    probes0 = []
    rs0 = RStore(RStoreConfig(**cfg), kvs=InMemoryKVS())

    def probe0(vids):
        full, _ = rs0.get_version(vids[-1])
        probes0.append(_composite_probes(full))

    vids0 = _run_steps(rs0, np.random.default_rng(w["seed"]), w["steps"],
                       lambda i: None, probe=probe0)

    # subject: doubly-indexed store over a replicated (optionally sharded,
    # optionally faulty/killed) backend, same interleaving — answered
    # through planned composite trees and index-only aggregates
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=fp["seed"] + i * R + r,
                           p_transient=fp["p_transient"],
                           p_timeout=fp["p_timeout"])
         for r in range(R)], write_quorum=1) for i in range(n_shards)]
    kvs1 = groups[0] if n_shards == 1 else ShardedKVS(groups)
    rs1 = RStore(RStoreConfig(**cfg), kvs=kvs1)
    rs1.create_index("tag", _attr2_extractor, n_buckets=3)
    rs1.create_index("hue", _attr2_extractor, n_buckets=3)
    kill_at = fp["kill_step"] % len(w["steps"]) if fp["kill"] else None
    probes1 = []

    def on_step(i):
        if i == kill_at:
            for g in groups:
                g.replicas[0].kill()

    def probe1(vids):
        v = vids[-1]
        res = rs1.snapshot().execute([
            Q.and_(Q.where(v, "tag", 1), Q.where(v, "hue", 2)),
            Q.or_(Q.where(v, "tag", 0), Q.where(v, "tag", 3)),
            Q.and_(Q.version(v), Q.not_(Q.where(v, "tag", 2))),
            Q.and_(Q.where_range(v, "hue", 0, 1),
                   Q.not_(Q.where(v, "tag", 0))),
            Q.count(Q.where(v, "tag", 1)),
            Q.distinct(v, "hue"),
        ])
        # the aggregates answered index-only: zero chunk-payload traffic
        assert res[4].stats.payload_round_trips == 0
        assert res[5].stats.payload_round_trips == 0
        probes1.append([r.value for r in res])

    vids1 = _run_steps(rs1, np.random.default_rng(w["seed"]), w["steps"],
                       on_step, probe=probe1)

    # identical interleaving → identical version ids, and every mid-run
    # composite plan was byte-identical to the brute-force oracle
    assert vids1 == vids0
    assert probes1 == probes0

    # retired versions are refused at PLAN time, live ones still answer
    retired = [vid for vid in range(rs1.graph.num_versions)
               if rs1.graph.is_retired(vid)]
    snap = rs1.snapshot()
    if retired:
        dead = retired[0]
        with pytest.raises(KeyError, match="retired"):
            snap.plan_batch([Q.and_(Q.where(dead, "tag", 1),
                                    Q.where(dead, "hue", 2))])
    full, _ = rs0.get_version(vids0[-1])
    got = snap.execute([Q.and_(Q.version(vids0[-1]),
                               Q.not_(Q.where(vids0[-1], "tag", 2)))])
    assert got[0].value == {pk: p for pk, p in full.items()
                            if _attr2_extractor(p)["tag"] != 2}


@given(maintenance_workload(), fault_plan())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_composite_plans_byte_identical_under_interleavings(w, fp):
    """For ANY interleaving of commit waves, retention prunings, compaction
    passes, and replica kills on a replicated flaky backend, every planned
    composite tree (and_/or_/not_ over where/where_range/version) is
    byte-identical to a brute-force full-scan oracle — mid-run after every
    step and at the end — aggregates answer index-only with zero
    chunk-payload round trips, and retired versions are refused at plan
    time."""
    _check_composite_planner_coherent(w, fp)


# fixed corner examples so the contract is still exercised when hypothesis
# is unavailable (conftest shims @given into a skip)
_COMPOSITE_EXAMPLES = [
    # retention retires versions mid-run (plan-time refusal has real
    # retired vids to refuse) + transient faults on a replicated shard
    ({"algorithm": "bottom_up", "k": 1, "batch": 3, "capacity": 512,
      "n_shards": 0, "seed": 131,
      "steps": [("commits", 4), ("retain", 2), ("commits", 3),
                ("compact", 0.6), ("commits", 2)]},
     {"R": 2, "n_shards": 1, "p_transient": 0.15, "p_timeout": 0.0,
      "kill": False, "kill_step": 0, "seed": 137}),
    # k>1 rebuild path + replica kill mid-run on a sharded router with
    # timeouts: composite plans must survive failover reads
    ({"algorithm": "shingle", "k": 3, "batch": 2, "capacity": 2048,
      "n_shards": 0, "seed": 139,
      "steps": [("commits", 5), ("compact", 1.0), ("retain", 4),
                ("commits", 2)]},
     {"R": 3, "n_shards": 3, "p_transient": 0.0, "p_timeout": 0.15,
      "kill": True, "kill_step": 2, "seed": 149}),
]


@pytest.mark.parametrize("w,fp", _COMPOSITE_EXAMPLES,
                         ids=["retain-refusal", "k3-kill-failover"])
def test_composite_planner_fixed_examples(w, fp):
    _check_composite_planner_coherent(w, fp)
