"""Secondary attribute indexes: varint vectorization regression, the
Projections.sorted_keys dirty-flag contract, SecondaryIndex unit behaviour
(postings, bucket persistence, staging), Q.where / Q.where_range planning
through the shared bitmap-kernel launch with exact post-filtering, and
coherence across flush / build / compaction / retention / drop_index /
CachingKVS."""
import numpy as np
import pytest

from repro.core import (CachingKVS, InMemoryKVS, Q, RStore, RStoreConfig,
                        SecondaryIndex, ShardedKVS, keep_last,
                        struct_extractor)
from repro.core import index as index_mod
from repro.core.index import Projections, varint_decode, varint_encode
from repro.core.secondary import datagen_extractor


# ---------------------------------------------------------------- varint sat.
def _varint_encode_ref(arr) -> bytes:
    """The original per-element/per-byte loop — the byte-format oracle the
    vectorized encoder must match exactly."""
    arr = np.asarray(arr, dtype=np.int64)
    out = bytearray()
    prev = 0
    for x in arr.tolist():
        d = x - prev
        prev = x
        while True:
            b = d & 0x7F
            d >>= 7
            if d:
                out.append(b | 0x80)
            else:
                out.append(b)
                break
    return bytes(out)


def test_varint_empty_input():
    assert varint_encode(np.empty(0, np.int64)) == b""
    assert len(varint_decode(b"")) == 0
    assert varint_decode(b"").dtype == np.int64


def test_varint_roundtrip_random():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 200))
        arr = np.sort(rng.integers(0, 1 << int(rng.integers(3, 40)), size=n))
        enc = varint_encode(arr)
        assert np.array_equal(varint_decode(enc), arr)


def test_varint_byte_format_and_size_parity_with_reference():
    rng = np.random.default_rng(1)
    cases = [np.array([0]), np.array([127]), np.array([128]),
             np.array([0, 0, 0]), np.arange(1000) * 129]
    for _ in range(30):
        n = int(rng.integers(1, 100))
        cases.append(np.sort(rng.integers(0, 1 << 35, size=n)))
    for arr in cases:
        enc = varint_encode(np.asarray(arr, np.int64))
        ref = _varint_encode_ref(arr)
        assert enc == ref                      # identical bytes => identical size
        assert np.array_equal(varint_decode(enc), np.asarray(arr, np.int64))


def test_varint_decode_discards_trailing_incomplete_group():
    enc = varint_encode(np.array([5, 300], dtype=np.int64))
    # continuation bit set on the final byte => incomplete group, dropped
    assert np.array_equal(varint_decode(enc + b"\x81"),
                          np.array([5, 300], dtype=np.int64))


# -------------------------------------------------- sorted_keys dirty flag
def test_sorted_keys_cache_survives_chunk_extension_of_existing_keys():
    """The documented invariant: the cache covers the key *set*, so adding
    chunks to existing keys must neither invalidate nor corrupt it — while
    a genuinely new key must show up."""
    p = Projections(version_chunks={}, key_chunks={}, n_chunks=4)
    p.extend_keys({3: np.array([0]), 1: np.array([1])})
    first = p.sorted_keys()
    assert first.tolist() == [1, 3]

    # same key set, more chunks: cache object is reused, still correct
    p.extend_keys({3: np.array([2])})
    again = p.sorted_keys()
    assert again is first
    assert again.tolist() == [1, 3]
    assert p.key_chunks[3].tolist() == [0, 2]

    # a new key dirties the cache (the old len-based heuristic could only
    # catch this by accident of counting)
    p.extend_keys({2: np.array([3])})
    assert p.sorted_keys().tolist() == [1, 2, 3]


# ------------------------------------------------------------ struct extractor
def test_struct_extractor_reads_little_endian_fields():
    ext = struct_extractor({"a": (0, 2), "b": (2, 4)})
    payload = (513).to_bytes(2, "little") + (70000).to_bytes(4, "little") + b"xx"
    assert ext(payload) == {"a": 513, "b": 70000}


def test_struct_extractor_short_payload_omits_field():
    ext = struct_extractor({"a": (0, 2), "b": (2, 4)})
    assert ext(b"\x07\x00\x01") == {"a": 7}    # "b" doesn't fit
    assert ext(b"") == {}


def test_struct_extractor_rejects_bad_spec():
    with pytest.raises(ValueError):
        struct_extractor({"a": (-1, 2)})
    with pytest.raises(ValueError):
        struct_extractor({"a": (0, 9)})


def test_datagen_extractor_layout():
    ext = datagen_extractor(2)
    payload = (11).to_bytes(4, "little") + (22).to_bytes(4, "little") + b"rest"
    assert ext(payload) == {"f0": 11, "f1": 22}


def test_datagen_attr_fields_are_extractable():
    from repro.core import DatasetSpec, generate

    spec = DatasetSpec(n_versions=6, n_base_records=30, payloads=True,
                       attr_fields=2, attr_cardinality=17, seed=3)
    graph = generate(spec)
    ext = datagen_extractor(2)
    seen = set()
    for rid in range(len(graph.store)):
        vals = ext(graph.store.payload(rid))
        assert set(vals) == {"f0", "f1"}
        assert all(0 <= v < spec.attr_cardinality for v in vals.values())
        seen.update(vals.values())
    assert len(seen) > 1               # values actually vary across records


# ------------------------------------------------------- SecondaryIndex unit
def _color(payload: bytes) -> dict:
    return {"color": payload[0]}


def test_secondary_index_add_remove_rebuild():
    idx = SecondaryIndex("color", _color, n_buckets=2)
    payloads = {0: b"\x01aa", 1: b"\x02bb", 2: b"\x01cc"}
    idx.add_chunks([(0, np.array([0, 1])), (1, np.array([2]))],
                   payloads.__getitem__)
    assert idx.postings_for(1).tolist() == [0, 1]
    assert idx.postings_for(2).tolist() == [0]
    assert idx.postings_for(99).tolist() == []
    assert [p.tolist() for p in idx.postings_in_range(1, 2)] == [[0, 1], [0]]

    idx.remove_chunks([0])
    assert idx.postings_for(1).tolist() == [1]
    assert idx.postings_for(2).tolist() == []  # value vanished entirely

    idx.rebuild({5: np.array([1])}, payloads.__getitem__)
    assert idx.postings_for(2).tolist() == [5]
    assert idx.postings_for(1).tolist() == []


def test_bucket_blob_roundtrip():
    idx = SecondaryIndex("c", _color, n_buckets=1)
    idx.postings = {7: np.array([0, 5, 6], np.int64),
                    -3: np.array([2], np.int64)}
    blob = idx._encode_bucket(0)
    dec = SecondaryIndex.decode_bucket(blob)
    assert set(dec) == {7, -3}
    assert dec[7].tolist() == [0, 5, 6]
    assert dec[-3].tolist() == [2]


def test_stage_writes_drains_dirty_and_deletes_emptied_buckets():
    idx = SecondaryIndex("color", _color, n_buckets=2)
    idx.add_chunks([(0, np.array([0]))], {0: b"\x03x"}.__getitem__)  # value 3 -> bucket 1
    writes, dels = idx.stage_writes()
    assert [k for k, _ in writes] == ["idx2/color/1"] and dels == []
    assert idx.stage_writes() == ([], [])      # drained

    idx.remove_chunks([0])                     # bucket 1 now empty
    writes, dels = idx.stage_writes()
    assert writes == [] and dels == ["idx2/color/1"]
    # deleting a never-stored bucket never emits a key (no spurious deletes)
    assert idx.stage_writes() == ([], [])


def test_index_load_roundtrips_persisted_postings():
    kvs = InMemoryKVS()
    idx = SecondaryIndex("color", _color, n_buckets=3)
    chunk_records = {0: np.array([0, 1]), 1: np.array([2])}
    payloads = {0: b"\x01a", 1: b"\x05b", 2: b"\x01c"}
    idx.add_chunks(sorted(chunk_records.items()), payloads.__getitem__)
    writes, _ = idx.stage_writes()
    kvs.multiput(writes)

    loaded = SecondaryIndex.load(kvs, "color", _color, chunk_records,
                                 payloads.__getitem__, n_buckets=3)
    assert set(loaded.postings) == set(idx.postings)
    for v in idx.postings:
        assert np.array_equal(loaded.postings[v], idx.postings[v])
    assert loaded.stored_bytes() == idx.stored_bytes() > 0
    # reverse map rebuilt too (compaction-ready)
    assert loaded.chunk_values[0].tolist() == [1, 5]


# ----------------------------------------------------------- store integration
def _mk(pk: int, color: int) -> bytes:
    return bytes([color]) + bytes([pk % 251]) * 24


def _make_store(cache_bytes=0, **cfg_kw):
    kvs = ShardedKVS([InMemoryKVS() for _ in range(4)])
    if cache_bytes:
        kvs = CachingKVS(kvs, cache_bytes=cache_bytes)
    cfg = RStoreConfig(capacity=1 << 9, batch_size=4, **cfg_kw)
    return RStore(cfg, kvs=kvs)


def _ingest(rs, n_pks=40, n_versions=6):
    vids = []
    with rs.writer() as w:
        v = w.init_root({pk: _mk(pk, pk % 5) for pk in range(n_pks)})
        vids.append(v)
        for i in range(n_versions):
            v = w.commit([v], adds={pk: _mk(pk, (pk + i) % 5)
                                    for pk in range(i, n_pks, 7)})
            vids.append(v)
    return vids


def _oracle(snap, ext, vid, pred):
    full = snap.execute([Q.version(vid)])[0].value
    return {pk: p for pk, p in full.items() if pred(ext(p)["color"])}


EXT = struct_extractor({"color": (0, 1)})


def test_where_matches_full_scan_oracle():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    snap = rs.snapshot()
    for vid in vids:
        for c in range(5):
            got = snap.execute([Q.where(vid, "color", c)])[0].value
            assert got == _oracle(snap, EXT, vid, lambda v: v == c)
        got = snap.execute([Q.where_range(vid, "color", 1, 3)])[0].value
        assert got == _oracle(snap, EXT, vid, lambda v: 1 <= v <= 3)


def test_create_index_after_ingest_indexes_existing_chunks():
    rs = _make_store()
    vids = _ingest(rs)
    rs.flush()
    rs.create_index("color", EXT)              # late registration
    snap = rs.snapshot()
    got = snap.execute([Q.where(vids[-1], "color", 2)])[0].value
    assert got == _oracle(snap, EXT, vids[-1], lambda v: v == 2)


def test_where_unknown_value_returns_empty_without_fetches():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    snap = rs.snapshot()
    r = snap.execute([Q.where(vids[-1], "color", 200)])[0]
    assert r.value == {} and r.stats.chunks_fetched == 0


def test_where_without_index_raises_keyerror_naming_attr():
    rs = _make_store()
    vids = _ingest(rs)
    with pytest.raises(KeyError, match="size"):
        rs.snapshot().execute([Q.where(vids[0], "size", 1)])


def test_create_index_requires_payloads_and_unique_attr():
    rs = _make_store(store_payloads=False)
    with pytest.raises(RuntimeError, match="store_payloads"):
        rs.create_index("color", EXT)
    rs = _make_store()
    rs.create_index("color", EXT)
    with pytest.raises(ValueError, match="already exists"):
        rs.create_index("color", EXT)


def test_drop_index_gcs_keys_and_disables_queries():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    rs.flush()
    assert any(k.startswith("idx2/") for s in rs.kvs.shards for k in s._d)
    rs.drop_index("color")
    assert not any(k.startswith("idx2/") for s in rs.kvs.shards for k in s._d)
    with pytest.raises(KeyError):
        rs.snapshot().execute([Q.where(vids[0], "color", 1)])
    with pytest.raises(KeyError):
        rs.drop_index("color")


def test_mixed_batch_shares_one_kernel_launch_and_one_fetch():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    snap = rs.snapshot()

    launches0 = index_mod.kops.BITMAP_LAUNCHES
    res = snap.execute([Q.where(vids[-1], "color", 2),
                        Q.where_range(vids[-1], "color", 0, 1),
                        Q.record(vids[-1], 3),
                        Q.range(vids[-1], 0, 9),
                        Q.version(vids[0])])
    # primary+secondary share ONE fused bitmap-program launch
    assert index_mod.kops.BITMAP_LAUNCHES - launches0 == 1
    # ONE interleaved multiget for the whole session (4 shards => <= 4 RTs,
    # sharded stats count per-shard round trips; assert batch-level count)
    assert res.batch.kvs_queries <= 4
    assert res[0].value == _oracle(snap, EXT, vids[-1], lambda v: v == 2)


def test_where_coherent_through_retention_and_compaction():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs, n_versions=8)
    rs.retain(keep_last(3))
    rep = rs.compact(liveness_threshold=1.0)
    assert rep.mode == "pass"
    snap = rs.snapshot()
    for vid in vids[-3:]:
        for c in range(5):
            got = snap.execute([Q.where(vid, "color", c)])[0].value
            assert got == _oracle(snap, EXT, vid, lambda v: v == c)
    # retired version: loud at plan time
    with pytest.raises(KeyError, match="retired"):
        snap.execute([Q.where(vids[0], "color", 1)])
    # zero orphaned idx2/ keys after the pass
    idx = rs._indexes["color"]
    in_kvs = {k for s in rs.kvs.shards for k in s._d if k.startswith("idx2/")}
    assert set(idx.stored_keys()) == in_kvs
    live = set(rs._chunk_records)
    for p in idx.postings.values():
        assert set(p.tolist()) <= live


def test_snapshot_refresh_repins_indexes_after_compaction():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs, n_versions=8)
    snap = rs.snapshot()
    rs.retain(keep_last(3))
    rs.compact(liveness_threshold=1.0)
    with pytest.raises(RuntimeError, match="refresh"):
        snap.execute([Q.where(vids[-1], "color", 2)])
    snap.refresh()
    got = snap.execute([Q.where(vids[-1], "color", 2)])[0].value
    assert got == _oracle(rs.snapshot(), EXT, vids[-1], lambda v: v == 2)


def test_where_coherent_through_full_build():
    rs = _make_store()
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    before = rs.snapshot().execute([Q.where(vids[-1], "color", 3)])[0].value
    rs.build()
    after = rs.snapshot().execute([Q.where(vids[-1], "color", 3)])[0].value
    assert after == before
    in_kvs = {k for s in rs.kvs.shards for k in s._d if k.startswith("idx2/")}
    assert set(rs._indexes["color"].stored_keys()) == in_kvs


def test_warm_cached_where_scan_is_zero_read_round_trips():
    rs = _make_store(cache_bytes=1 << 22)
    rs.create_index("color", EXT)
    vids = _ingest(rs)
    snap = rs.snapshot()
    q = [Q.where(vids[-1], "color", 2)]
    cold = snap.execute(q)
    assert cold.batch.kvs_queries >= 1
    warm = snap.execute(q)
    assert warm.batch.kvs_queries == 0         # all from cache
    assert warm[0].value == cold[0].value


def test_cached_where_coherent_across_compaction_epoch():
    rs = _make_store(cache_bytes=1 << 22)
    rs.create_index("color", EXT)
    vids = _ingest(rs, n_versions=8)
    snap = rs.snapshot()
    expect = {c: snap.execute([Q.where(vids[-1], "color", c)])[0].value
              for c in range(5)}               # cache now warm
    rs.retain(keep_last(3))
    rs.compact(liveness_threshold=1.0)         # invalidates superseded keys
    snap = rs.snapshot()
    for c in range(5):
        got = snap.execute([Q.where(vids[-1], "color", c)])[0].value
        assert got == expect[c]


def test_storage_stats_price_secondary_indexes():
    rs = _make_store()
    rs.create_index("color", EXT)
    _ingest(rs)
    rs.flush()
    st = rs.storage_stats()
    assert st["secondary_index_bytes"] > 0
    rep = st["secondary_indexes"]["color"]
    assert rep["n_values"] == 5 and rep["stored_bytes"] > 0


def test_selective_where_fetches_fewer_chunks_than_full_version():
    """The headline win: a selective predicate touches a fraction of the
    version's span (the bench gates this at <=25% on a bigger workload)."""
    rng = np.random.default_rng(7)
    rs = _make_store()
    ext = struct_extractor({"tag": (0, 2)})
    rs.create_index("tag", ext)
    payload = lambda tag: int(tag).to_bytes(2, "little") + b"z" * 40
    with rs.writer() as w:
        v = w.init_root({pk: payload(rng.integers(0, 500))
                         for pk in range(600)})
    snap = rs.snapshot()
    full = snap.execute([Q.version(v)])[0]
    tag = int(ext(next(iter(full.value.values())))["tag"])
    flt = snap.execute([Q.where(v, "tag", tag)])[0]
    assert flt.value == {pk: p for pk, p in full.value.items()
                         if ext(p)["tag"] == tag}
    assert 0 < flt.stats.chunks_fetched <= 0.25 * full.stats.chunks_fetched
