"""Version graph, types, datagen, and cost-model tests (unit + property)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import costmodel, datagen
from repro.core.types import (CompositeKey, pack_ck, pack_ck_array, unpack_ck,
                              unpack_ck_array)


# -------------------------------------------------------------------- types
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_composite_key_roundtrip(k, v):
    assert unpack_ck(pack_ck(k, v)) == (k, v)


def test_composite_key_rejects_out_of_range():
    with pytest.raises(ValueError):
        pack_ck(2**31, 0)


@given(st.lists(st.tuples(st.integers(0, 2**31 - 1), st.integers(0, 2**20)),
                min_size=1, max_size=50))
def test_composite_key_array_roundtrip(pairs):
    ks = np.array([p[0] for p in pairs], dtype=np.int64)
    vs = np.array([p[1] for p in pairs], dtype=np.int64)
    k2, v2 = unpack_ck_array(pack_ck_array(ks, vs))
    np.testing.assert_array_equal(ks, k2)
    np.testing.assert_array_equal(vs, v2)


def test_composite_key_uniqueness():
    assert pack_ck(1, 2) != pack_ck(2, 1)
    assert CompositeKey(3, 4).packed() == pack_ck(3, 4)


# ------------------------------------------------------------------ datagen
@pytest.mark.parametrize("branch,merge", [(0.0, 0.0), (0.15, 0.0), (0.1, 0.1)])
def test_generated_graph_invariants(branch, merge):
    spec = datagen.DatasetSpec(n_versions=60, n_base_records=200,
                               pct_update=0.1, branch_prob=branch,
                               merge_prob=merge, seed=5)
    g = datagen.generate(spec)
    g.check_invariants()
    assert g.num_versions == 60
    stats = datagen.dataset_stats(g)
    assert stats["unique_records"] >= 200
    # dedupe must pay: total logical bytes >> unique bytes for small updates
    assert stats["total_bytes"] > 3 * stats["unique_bytes"]


def test_generation_is_deterministic():
    spec = datagen.DatasetSpec(n_versions=30, n_base_records=100, seed=9,
                               payloads=True, p_d=0.1)
    g1, g2 = datagen.generate(spec), datagen.generate(spec)
    np.testing.assert_array_equal(g1.store.cks, g2.store.cks)
    assert g1.store.payload(5) == g2.store.payload(5)


def test_chain_dataset_is_chain():
    g = datagen.generate(datagen.DatasetSpec(n_versions=40, branch_prob=0.0,
                                             n_base_records=50))
    assert g.avg_depth() == 39
    assert len(g.leaves()) == 1


def test_bounded_change_payloads():
    spec = datagen.DatasetSpec(n_versions=20, n_base_records=50, seed=2,
                               payloads=True, p_d=0.05, pct_update=0.2,
                               frac_modify=1.0, frac_insert=0.0, frac_delete=0.0)
    g = datagen.generate(spec)
    origins = g.store.origin_versions()
    keys = g.store.keys()
    # find a modified record and its parent record: same key, parent version
    changed = 0
    for rid in range(len(g.store)):
        if origins[rid] == 0:
            continue
        parent_v = g.tree_parent(int(origins[rid]))
        # parent record = same key live at parent version
        pm = g.members(parent_v)
        pk = keys[rid]
        prid = [r for r in pm if keys[r] == pk]
        if not prid:
            continue
        a, b = g.store.payload(int(prid[0])), g.store.payload(rid)
        if len(a) == len(b):
            diff = sum(x != y for x, y in zip(a, b))
            assert diff <= max(1, int(0.05 * len(a))) + 1
            changed += 1
        if changed > 10:
            break
    assert changed > 0


# ------------------------------------------------------- membership algebra
@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_delta_algebra(seed):
    """Δ+ ∩ Δ− = ∅; member(child) = (member(parent) \\ Δ−) ∪ Δ+;
    reversing an edge swaps Δ+/Δ− (the paper's symmetry)."""
    spec = datagen.DatasetSpec(n_versions=25, n_base_records=80,
                               pct_update=0.15, branch_prob=0.2, seed=seed)
    g = datagen.generate(spec)
    for v in g.versions[1:]:
        d = g.tree_delta[v]
        p = g.tree_parent(v)
        assert np.intersect1d(d.adds, d.dels).size == 0
        recon = np.union1d(np.setdiff1d(g.members(p), d.dels), d.adds)
        np.testing.assert_array_equal(recon, g.members(v))
        r = d.reversed()
        np.testing.assert_array_equal(r.adds, d.dels)
        back = np.union1d(np.setdiff1d(g.members(v), r.dels), r.adds)
        np.testing.assert_array_equal(back, g.members(p))


def test_record_version_csr_consistent():
    g = datagen.generate(datagen.DatasetSpec(n_versions=30, n_base_records=60,
                                             branch_prob=0.2, seed=3))
    indptr, vids = g.record_version_csr()
    # rebuild memberships from CSR and compare
    rebuilt = {v: [] for v in g.versions}
    for r in range(len(g.store)):
        for v in vids[indptr[r]:indptr[r + 1]]:
            rebuilt[int(v)].append(r)
    for v, m in g.memberships().items():
        np.testing.assert_array_equal(np.sort(rebuilt[v]), m)


# ---------------------------------------------------------------- costmodel
def test_costmodel_table1_orderings():
    w = costmodel.Workload(n=100, m_v=1000, d=0.05, c=0.3, s=200, s_c=4000)
    ind = costmodel.independent_chunking(w)
    dl = costmodel.delta(w)
    sc = costmodel.subchunk(w)
    sa = costmodel.single_address(w)
    rs = costmodel.rstore(w, span_factor=1.3)
    # storage: independent is worst; delta/subchunk compress best
    assert ind["storage"] > sa["storage"] > dl["storage"]
    assert dl["storage"] == sc["storage"]
    # version retrieval #queries: chunked ≪ single-address
    assert rs["version_queries"] < sa["version_queries"] / 10
    # point queries: delta is catastrophically worse (fetches half the chain)
    assert dl["point_bytes"] > 50 * rs["point_bytes"]
    assert dl["point_queries"] == w.n / 2 and rs["point_queries"] == 1
