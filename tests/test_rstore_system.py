"""End-to-end RStore behaviour: ingest → chunking → queries are *exact*
against the version-graph oracle, across algorithms, compression levels,
online batching, merges, and the sharded device KVS."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import RStore, RStoreConfig, datagen
from repro.core.index import varint_decode, varint_encode
from repro.core.kvs import InMemoryKVS, ShardedDeviceKVS


def _pay(rng, n=100):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def _oracle(rs, vid):
    m = rs.graph.members(vid)
    keys = rs.graph.store.keys()
    return {int(keys[r]): rs.graph.store.payload(int(r)) for r in m}


def _build_branched(rs, rng, n_keys=40):
    v0 = rs.init_root({k: _pay(rng) for k in range(n_keys)})
    v1 = rs.commit([v0], adds={3: _pay(rng), n_keys: _pay(rng)}, dels=[7])
    v2 = rs.commit([v0], adds={3: _pay(rng), n_keys + 1: _pay(rng)}, dels=[2])
    v3 = rs.commit([v1], adds={}, dels=[2])
    v4 = rs.commit([v2], adds={3: _pay(rng)})
    v5 = rs.commit([v3, v4], adds={n_keys + 10: _pay(rng)})
    return [v0, v1, v2, v3, v4, v5]


@pytest.mark.parametrize("algo", ["bottom_up", "shingle", "depth_first",
                                  "breadth_first"])
@pytest.mark.parametrize("k", [1, 3])
def test_queries_exact(algo, k):
    rng = np.random.default_rng(11)
    rs = RStore(RStoreConfig(algorithm=algo, capacity=1024, batch_size=4, k=k))
    vids = _build_branched(rs, rng)
    for v in vids:
        got, _ = rs.get_version(v)
        assert got == _oracle(rs, v)
    # point
    got, _ = rs.get_record(vids[3], 3)
    assert got == _oracle(rs, vids[3])[3]
    # range
    got, _ = rs.get_range(vids[4], 10, 20)
    assert got == {k_: v for k_, v in _oracle(rs, vids[4]).items() if 10 <= k_ <= 20}
    # evolution: one record per origin version of key 3
    evo, _ = rs.get_evolution(3)
    assert [o for o, _ in evo] == [0, 1, 2, 4]


def test_absent_record_returns_none():
    rng = np.random.default_rng(1)
    rs = RStore(RStoreConfig(batch_size=2))
    v0 = rs.init_root({1: _pay(rng), 2: _pay(rng)})
    v1 = rs.commit([v0], adds={}, dels=[2])
    got, _ = rs.get_record(v1, 2)
    assert got is None
    got, _ = rs.get_record(v1, 999)
    assert got is None


def test_online_batches_match_oracle_incrementally():
    """Many small batches: every flush keeps all past versions exact."""
    rng = np.random.default_rng(5)
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=2048, batch_size=5))
    vid = rs.init_root({k: _pay(rng) for k in range(60)})
    history = [vid]
    for i in range(23):
        vid = rs.commit([vid], adds={int(rng.integers(0, 60)): _pay(rng),
                                     100 + i: _pay(rng)})
        history.append(vid)
        if i % 7 == 0:
            for v in history[:: max(1, len(history) // 4)]:
                got, _ = rs.get_version(v)
                assert got == _oracle(rs, v)
    for v in history:
        got, _ = rs.get_version(v)
        assert got == _oracle(rs, v)


def test_chunked_retrieval_uses_one_roundtrip_per_table():
    """The too-many-queries fix: Q1 costs O(1) KVS round-trips, not O(m)."""
    rng = np.random.default_rng(2)
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=4096, batch_size=500))
    vid = rs.init_root({k: _pay(rng) for k in range(300)})
    rs.flush()
    _, stats = rs.get_version(vid)
    assert stats.kvs_queries <= 2          # chunks + maps, each one multiget
    assert stats.chunks_fetched >= 5


def test_sharded_device_kvs_backend():
    """Same exactness through the JAX device-array KVS."""
    rng = np.random.default_rng(3)
    rs = RStore(RStoreConfig(algorithm="depth_first", capacity=1024,
                             batch_size=3),
                kvs=ShardedDeviceKVS(slot_bytes=2048, n_slots=64))
    vids = _build_branched(rs, rng)
    for v in vids:
        got, _ = rs.get_version(v)
        assert got == _oracle(rs, v)


def test_sharded_kvs_roundtrip_and_spanning_slots():
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=4)
    rng = np.random.default_rng(0)
    blobs = {f"k{i}": rng.integers(0, 256, int(rng.integers(1, 300)),
                                   dtype=np.uint8).tobytes() for i in range(20)}
    for k, v in blobs.items():
        kvs.put(k, v)
    got = kvs.multiget(list(blobs))
    assert got == list(blobs.values())
    assert kvs.stats.n_queries == 1


@given(st.lists(st.integers(0, 2**40), min_size=0, max_size=60))
def test_varint_roundtrip(xs):
    arr = np.asarray(sorted(xs), dtype=np.int64)
    np.testing.assert_array_equal(varint_decode(varint_encode(arr)), arr)


def test_index_compression_shrinks():
    g = datagen.generate(datagen.DatasetSpec(n_versions=100, n_base_records=500,
                                             pct_update=0.05, seed=6))
    from repro.core.index import Projections
    from repro.core.partition import BottomUpPartitioner
    part = BottomUpPartitioner().partition(g, 8192)
    proj = Projections.build(g, part)
    raw = proj.raw_size()
    comp = proj.compressed_size()
    assert comp["version_chunks_bytes"] < raw["version_chunks_bytes"] / 3


def test_compression_reduces_stored_bytes():
    """§3.4: with highly-similar payloads (small P_d), k>1 + delta encoding
    must store fewer bytes than k=1."""
    spec = datagen.DatasetSpec(n_versions=40, n_base_records=80, seed=7,
                               payloads=True, p_d=0.02, record_size=512,
                               pct_update=0.2, frac_modify=1.0,
                               frac_insert=0.0, frac_delete=0.0)

    def build(k):
        g = datagen.generate(spec)
        rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=8192, k=k,
                                 batch_size=10**9))
        rs.graph = g
        rs._grow_r2c()
        rs.build()
        return rs

    s1 = build(1).storage_stats()["stored_chunk_bytes"]
    s5 = build(5).storage_stats()["stored_chunk_bytes"]
    assert s5 < s1 * 0.7


def test_storage_dedupe():
    """Records shared across versions are stored once (§2.2 requirement 1)."""
    rng = np.random.default_rng(8)
    rs = RStore(RStoreConfig(capacity=4096, batch_size=100))
    vid = rs.init_root({k: _pay(rng, 200) for k in range(100)})
    for i in range(10):                      # touch 1 record per version
        vid = rs.commit([vid], adds={0: _pay(rng, 200)})
    rs.flush()
    stats = rs.storage_stats()
    # logical data = 11 versions × 100 records; stored ≈ 110 unique records
    assert stats["raw_unique_bytes"] <= 200 * 111
    assert stats["stored_chunk_bytes"] < 1.5 * stats["raw_unique_bytes"]
