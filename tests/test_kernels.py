"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, plus hypothesis property tests on the wrappers."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import bitmap as kbitmap
from repro.kernels import deltaenc as kdelta
from repro.kernels import minhash as kminhash
from repro.kernels import ops, ref


# ------------------------------------------------------------------ minhash
@pytest.mark.parametrize("R,D", [(128, 128), (256, 128), (128, 384), (512, 256)])
@pytest.mark.parametrize("L", [1, 4, 16])
def test_minhash_kernel_matches_ref(R, D, L):
    rng = np.random.default_rng(R * 1000 + D + L)
    vers = rng.integers(0, 10_000, size=(R, D)).astype(np.int32)
    vers[rng.random((R, D)) < 0.4] = -1
    a, b = ops.hash_family(L, seed=7)
    got = kminhash.minhash(jnp.asarray(vers), jnp.asarray(a), jnp.asarray(b),
                           interpret=True)
    want = ref.minhash_ref(jnp.asarray(vers), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minhash_empty_rows_are_maxval():
    vers = np.full((128, 128), -1, dtype=np.int32)
    a, b = ops.hash_family(3)
    out = ops.minhash_padded(vers, a, b)
    assert (out == 0xFFFFFFFF).all()


def test_minhash_is_permutation_invariant():
    """Min-hash of a set cannot depend on element order (the property the
    partitioner relies on)."""
    rng = np.random.default_rng(0)
    row = rng.choice(5000, size=60, replace=False).astype(np.int32)
    a, b = ops.hash_family(8, 3)
    m1 = ops.minhash_padded(row[None, :], a, b)
    m2 = ops.minhash_padded(rng.permutation(row)[None, :], a, b)
    np.testing.assert_array_equal(m1, m2)


@given(st.lists(st.lists(st.integers(0, 2**20), min_size=0, max_size=40),
                min_size=1, max_size=20))
@settings(max_examples=25, deadline=None)
def test_minhash_csr_equals_python_min(rows):
    indptr = np.cumsum([0] + [len(r) for r in rows]).astype(np.int64)
    col = np.asarray([v for r in rows for v in r], dtype=np.int64)
    a, b = ops.hash_family(4, 1)
    got = ops.minhash_csr(indptr, col, a, b)
    for i, r in enumerate(rows):
        for l in range(4):
            if not r:
                assert got[i, l] == 0xFFFFFFFF
            else:
                want = min(((int(a[l]) * v + int(b[l])) & 0xFFFFFFFF) for v in set(r))
                assert got[i, l] == want


# ---------------------------------------------------------------- xor delta
@pytest.mark.parametrize("N,W", [(128, 128), (256, 256), (384, 512)])
def test_xor_delta_kernel_matches_ref(N, W):
    rng = np.random.default_rng(N + W)
    p = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    c = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    d, cnt = kdelta.xor_delta(jnp.asarray(p), jnp.asarray(c), interpret=True)
    dr, cr = ref.xor_delta_ref(jnp.asarray(p), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cr))


@given(st.binary(min_size=0, max_size=300), st.binary(min_size=0, max_size=300))
@settings(max_examples=50, deadline=None)
def test_xor_delta_bytes_roundtrip(parent, child):
    """decode(parent, encode(parent, child)) == child — the §3.4 invariant."""
    w = max(len(parent), len(child))
    delta, _ = ops.xor_delta_bytes(parent.ljust(w, b"\0"), child.ljust(w, b"\0"))
    back, _ = ops.xor_delta_bytes(parent.ljust(w, b"\0"), delta)
    assert back[:len(child)] == child
    assert all(x == 0 for x in back[len(child):])


def test_xor_delta_identical_is_zero():
    p = np.arange(256 * 128, dtype=np.uint32).reshape(256, 128)
    d, cnt = ops.xor_delta_batch(p, p)
    assert (d == 0).all() and (cnt == 0).all()


# ------------------------------------------------------------------- bitmap
@pytest.mark.parametrize("N,W", [(128, 128), (256, 256)])
def test_bitmap_kernel_matches_ref(N, W):
    rng = np.random.default_rng(N * 7 + W)
    bms = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    row = rng.integers(0, 2**32, size=(1, W), dtype=np.uint32)
    a1, c1 = kbitmap.and_popcount(jnp.asarray(bms), jnp.asarray(row), interpret=True)
    a2, c2 = ref.and_popcount_ref(jnp.asarray(bms), jnp.asarray(row))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@given(st.integers(1, 64), st.integers(1, 33), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_bitmap_popcount_exact(n, w, seed):
    rng = np.random.default_rng(seed)
    bms = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    row = rng.integers(0, 2**32, size=w, dtype=np.uint32)
    anded, cnt = ops.and_popcount_batch(bms, row)
    want = np.array([sum(bin(int(x)).count("1") for x in r) for r in bms & row])
    np.testing.assert_array_equal(anded, bms & row)
    np.testing.assert_array_equal(cnt, want)
