"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, plus hypothesis property tests on the wrappers."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import bitmap as kbitmap
from repro.kernels import deltaenc as kdelta
from repro.kernels import minhash as kminhash
from repro.kernels import ops, ref


# ------------------------------------------------------------------ minhash
@pytest.mark.parametrize("R,D", [(128, 128), (256, 128), (128, 384), (512, 256)])
@pytest.mark.parametrize("L", [1, 4, 16])
def test_minhash_kernel_matches_ref(R, D, L):
    rng = np.random.default_rng(R * 1000 + D + L)
    vers = rng.integers(0, 10_000, size=(R, D)).astype(np.int32)
    vers[rng.random((R, D)) < 0.4] = -1
    a, b = ops.hash_family(L, seed=7)
    got = kminhash.minhash(jnp.asarray(vers), jnp.asarray(a), jnp.asarray(b),
                           interpret=True)
    want = ref.minhash_ref(jnp.asarray(vers), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minhash_empty_rows_are_maxval():
    vers = np.full((128, 128), -1, dtype=np.int32)
    a, b = ops.hash_family(3)
    out = ops.minhash_padded(vers, a, b)
    assert (out == 0xFFFFFFFF).all()


def test_minhash_is_permutation_invariant():
    """Min-hash of a set cannot depend on element order (the property the
    partitioner relies on)."""
    rng = np.random.default_rng(0)
    row = rng.choice(5000, size=60, replace=False).astype(np.int32)
    a, b = ops.hash_family(8, 3)
    m1 = ops.minhash_padded(row[None, :], a, b)
    m2 = ops.minhash_padded(rng.permutation(row)[None, :], a, b)
    np.testing.assert_array_equal(m1, m2)


@given(st.lists(st.lists(st.integers(0, 2**20), min_size=0, max_size=40),
                min_size=1, max_size=20))
@settings(max_examples=25, deadline=None)
def test_minhash_csr_equals_python_min(rows):
    indptr = np.cumsum([0] + [len(r) for r in rows]).astype(np.int64)
    col = np.asarray([v for r in rows for v in r], dtype=np.int64)
    a, b = ops.hash_family(4, 1)
    got = ops.minhash_csr(indptr, col, a, b)
    for i, r in enumerate(rows):
        for l in range(4):
            if not r:
                assert got[i, l] == 0xFFFFFFFF
            else:
                want = min(((int(a[l]) * v + int(b[l])) & 0xFFFFFFFF) for v in set(r))
                assert got[i, l] == want


# ---------------------------------------------------------------- xor delta
@pytest.mark.parametrize("N,W", [(128, 128), (256, 256), (384, 512)])
def test_xor_delta_kernel_matches_ref(N, W):
    rng = np.random.default_rng(N + W)
    p = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    c = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    d, cnt = kdelta.xor_delta(jnp.asarray(p), jnp.asarray(c), interpret=True)
    dr, cr = ref.xor_delta_ref(jnp.asarray(p), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cr))


@given(st.binary(min_size=0, max_size=300), st.binary(min_size=0, max_size=300))
@settings(max_examples=50, deadline=None)
def test_xor_delta_bytes_roundtrip(parent, child):
    """decode(parent, encode(parent, child)) == child — the §3.4 invariant."""
    w = max(len(parent), len(child))
    delta, _ = ops.xor_delta_bytes(parent.ljust(w, b"\0"), child.ljust(w, b"\0"))
    back, _ = ops.xor_delta_bytes(parent.ljust(w, b"\0"), delta)
    assert back[:len(child)] == child
    assert all(x == 0 for x in back[len(child):])


def test_xor_delta_identical_is_zero():
    p = np.arange(256 * 128, dtype=np.uint32).reshape(256, 128)
    d, cnt = ops.xor_delta_batch(p, p)
    assert (d == 0).all() and (cnt == 0).all()


# ------------------------------------------------------------------- bitmap
@pytest.mark.parametrize("N,W", [(128, 128), (256, 256)])
def test_bitmap_kernel_matches_ref(N, W):
    rng = np.random.default_rng(N * 7 + W)
    bms = rng.integers(0, 2**32, size=(N, W), dtype=np.uint32)
    row = rng.integers(0, 2**32, size=(1, W), dtype=np.uint32)
    a1, c1 = kbitmap.and_popcount(jnp.asarray(bms), jnp.asarray(row), interpret=True)
    a2, c2 = ref.and_popcount_ref(jnp.asarray(bms), jnp.asarray(row))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@given(st.integers(1, 64), st.integers(1, 33), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_bitmap_popcount_exact(n, w, seed):
    rng = np.random.default_rng(seed)
    bms = rng.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    row = rng.integers(0, 2**32, size=w, dtype=np.uint32)
    anded, cnt = ops.and_popcount_batch(bms, row)
    want = np.array([sum(bin(int(x)).count("1") for x in r) for r in bms & row])
    np.testing.assert_array_equal(anded, bms & row)
    np.testing.assert_array_equal(cnt, want)


# ---------------------------------------------------------------- bitmap VM
def _vm_oracle(regs: np.ndarray, prog: np.ndarray):
    """Plain-python simulation of the bitmap VM (independent of ref.py)."""
    r = regs.copy()
    for op, dst, lhs, rhs in np.asarray(prog, dtype=np.int64).reshape(-1, 4):
        a, b = r[lhs], r[rhs]
        r[dst] = (a & b if op == kbitmap.OP_AND
                  else a | b if op == kbitmap.OP_OR else a & ~b)
    cnt = np.array([sum(bin(int(x)).count("1") for x in row) for row in r])
    return r, cnt


def _random_prog(rng, S: int, P: int) -> np.ndarray:
    prog = np.empty((P, 4), dtype=np.int32)
    prog[:, 0] = rng.integers(0, 3, size=P)
    prog[:, 1:] = rng.integers(0, S, size=(P, 3))
    return prog


@pytest.mark.parametrize("S,W,P", [(128, 128, 8), (128, 256, 32), (256, 128, 1)])
def test_bitmap_vm_kernel_matches_ref(S, W, P):
    rng = np.random.default_rng(S * 13 + W + P)
    regs = rng.integers(0, 2**32, size=(S, W), dtype=np.uint32)
    prog = _random_prog(rng, S, P)
    o1, c1 = kbitmap.bitmap_vm(jnp.asarray(regs), jnp.asarray(prog),
                               interpret=True)
    o2, c2 = ref.bitmap_vm_ref(jnp.asarray(regs), jnp.asarray(prog))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("op", [kbitmap.OP_AND, kbitmap.OP_OR, kbitmap.OP_ANDNOT])
def test_bitmap_vm_each_op_exact(op):
    rng = np.random.default_rng(40 + op)
    regs = rng.integers(0, 2**32, size=(4, 9), dtype=np.uint32)
    prog = np.array([[op, 3, 0, 1]], dtype=np.int32)
    out, cnt = ops.bitmap_vm_batch(regs, prog)
    want, wcnt = _vm_oracle(regs, prog)
    np.testing.assert_array_equal(out, want)
    np.testing.assert_array_equal(cnt, wcnt)


def test_bitmap_vm_empty_program_passes_through():
    rng = np.random.default_rng(3)
    regs = rng.integers(0, 2**32, size=(5, 7), dtype=np.uint32)
    out, cnt = ops.bitmap_vm_batch(regs, np.zeros((0, 4), dtype=np.int32))
    np.testing.assert_array_equal(out, regs)
    want = np.array([sum(bin(int(x)).count("1") for x in r) for r in regs])
    np.testing.assert_array_equal(cnt, want)
    # kernel-level empty program too (the P == 0 short-circuit)
    o, c = kbitmap.bitmap_vm(jnp.asarray(regs), jnp.zeros((0, 4), jnp.int32))
    np.testing.assert_array_equal(np.asarray(o), regs)
    np.testing.assert_array_equal(np.asarray(c), want)


def test_bitmap_vm_all_zero_bitmaps():
    regs = np.zeros((6, 11), dtype=np.uint32)
    prog = np.array([[kbitmap.OP_OR, 4, 0, 1],
                     [kbitmap.OP_ANDNOT, 5, 2, 3]], dtype=np.int32)
    out, cnt = ops.bitmap_vm_batch(regs, prog)
    assert (out == 0).all() and (cnt == 0).all()


@given(st.integers(2, 24), st.integers(1, 17), st.integers(0, 12),
       st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_bitmap_vm_property_matches_oracle(s, w, p, seed):
    rng = np.random.default_rng(seed)
    regs = rng.integers(0, 2**32, size=(s, w), dtype=np.uint32)
    prog = _random_prog(rng, s, p)
    out, cnt = ops.bitmap_vm_batch(regs, prog)
    want, wcnt = _vm_oracle(regs, prog)
    np.testing.assert_array_equal(out, want)
    np.testing.assert_array_equal(cnt, wcnt)


def test_bitmap_vm_operand_out_of_range_raises():
    regs = np.zeros((4, 4), dtype=np.uint32)
    with pytest.raises(ValueError, match="out of range"):
        ops.bitmap_vm_batch(regs, np.array([[0, 4, 0, 1]], dtype=np.int32))
    with pytest.raises(ValueError, match="out of range"):
        ops.bitmap_vm_batch(regs, np.array([[0, 0, -1, 1]], dtype=np.int32))


def test_bitmap_vm_counts_one_launch():
    regs = np.ones((3, 3), dtype=np.uint32)
    before = ops.BITMAP_LAUNCHES
    ops.bitmap_vm_batch(regs, np.zeros((0, 4), dtype=np.int32))
    assert ops.BITMAP_LAUNCHES - before == 1
