"""Plan/execute session API: batched results must be byte-identical to the
sequential ``get_*`` wrappers, a whole mixed batch must cost exactly one KVS
round trip, and reads must not mutate store state."""
import numpy as np
import pytest

from repro.core import Q, RStore, RStoreConfig
from repro.core.api import Snapshot
from repro.core.kvs import InMemoryKVS


def _pay(rng, n=100):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def _build_branched(rs, rng, n_keys=40):
    v0 = rs.init_root({k: _pay(rng) for k in range(n_keys)})
    v1 = rs.commit([v0], adds={3: _pay(rng), n_keys: _pay(rng)}, dels=[7])
    v2 = rs.commit([v0], adds={3: _pay(rng), n_keys + 1: _pay(rng)}, dels=[2])
    v3 = rs.commit([v1], adds={}, dels=[2])
    v4 = rs.commit([v2], adds={3: _pay(rng)})
    v5 = rs.commit([v3, v4], adds={n_keys + 10: _pay(rng)})
    return [v0, v1, v2, v3, v4, v5]


def _mixed_queries(vids, rng, n=64, n_keys=40):
    qs = []
    for i in range(n):
        v = vids[i % len(vids)]
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.integers(0, n_keys))))
        elif kind == 2:
            lo = int(rng.integers(0, n_keys))
            qs.append(Q.range(v, lo, lo + 10))
        else:
            qs.append(Q.evolution(int(rng.integers(0, n_keys))))
    return qs


# ----------------------------------------------------------- equivalence
@pytest.mark.parametrize("algo", ["bottom_up", "shingle", "depth_first"])
@pytest.mark.parametrize("k", [1, 3])
def test_batched_equals_sequential(algo, k):
    """Batched execute across roots, deltas, merges, k>1 builds must match
    the per-query wrappers byte for byte."""
    rng = np.random.default_rng(11)
    rs = RStore(RStoreConfig(algorithm=algo, capacity=1024, batch_size=4, k=k))
    vids = _build_branched(rs, rng)
    qs = _mixed_queries(vids, rng)
    res = rs.snapshot().execute(qs)
    for q, r in zip(qs, res):
        if q.kind == "version":
            assert r.value == rs.get_version(q.vid)[0]
        elif q.kind == "record":
            assert r.value == rs.get_record(q.vid, q.pk)[0]
        elif q.kind == "range":
            assert r.value == rs.get_range(q.vid, q.key_lo, q.key_hi)[0]
        elif q.kind == "evolution":
            assert r.value == rs.get_evolution(q.pk)[0]


def test_multi_point_records_query():
    rng = np.random.default_rng(4)
    rs = RStore(RStoreConfig(capacity=1024, batch_size=4))
    vids = _build_branched(rs, rng)
    res = rs.snapshot().execute([Q.records(vids[5], [0, 3, 5, 7, 9999])])
    got = res[0].value
    expect = {}
    for pk in (0, 3, 5, 7, 9999):
        rec, _ = rs.get_record(vids[5], pk)
        if rec is not None:
            expect[pk] = rec
    assert got == expect
    assert 9999 not in got          # absent keys omitted, not None-valued


# --------------------------------------------------------- round trips
def test_64_query_batch_is_one_kvs_round_trip():
    """The acceptance criterion: 64 mixed queries → exactly 1 InMemoryKVS
    round trip (the sequential path pays ≥ 1 per query; the seed paid 2)."""
    rng = np.random.default_rng(2)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=2048,
                             batch_size=8), kvs=kvs)
    vids = _build_branched(rs, rng)
    rs.flush()
    snap = rs.snapshot()
    qs = _mixed_queries(vids, rng, n=64)

    q0 = kvs.stats.n_queries
    res = snap.execute(qs)
    assert kvs.stats.n_queries - q0 == 1
    assert res.batch.kvs_queries == 1
    assert len(res) == 64

    # sequential single-query sessions: one round trip each
    q0 = kvs.stats.n_queries
    for q in qs:
        snap.execute([q])
    assert kvs.stats.n_queries - q0 >= 64


def test_batch_stats_attribute_shared_bytes_once():
    rng = np.random.default_rng(3)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    vids = _build_branched(rs, rng)
    rs.flush()
    # same Q1 five times: candidates identical, fetched once
    b0 = kvs.stats.bytes_fetched
    res = rs.snapshot().execute([Q.version(vids[0])] * 5)
    fetched = kvs.stats.bytes_fetched - b0
    assert res.batch.bytes_fetched == fetched
    # per-query stats each see the full candidate bytes (attribution),
    # but the backend only moved them once
    assert res[0].stats.bytes_fetched == fetched
    assert sum(r.stats.bytes_fetched for r in res) == 5 * fetched
    assert all(r.value == res[0].value for r in res)


def test_empty_batch_and_empty_candidates():
    rng = np.random.default_rng(5)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    vids = _build_branched(rs, rng)
    rs.flush()
    snap = rs.snapshot()
    assert list(snap.execute([])) == []
    q0 = kvs.stats.n_queries
    res = snap.execute([Q.record(vids[0], 12345), Q.evolution(54321)])
    assert kvs.stats.n_queries == q0      # nothing to fetch → 0 round trips
    assert res[0].value is None
    assert res[1].value == []


# ----------------------------------------------------- snapshot semantics
def test_snapshot_reads_do_not_flush_with_auto_flush_off():
    rng = np.random.default_rng(6)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=100, auto_flush=False))
    v0 = rs.init_root({k: _pay(rng) for k in range(20)})
    with pytest.raises(RuntimeError):
        rs.snapshot()                     # unflushed deltas must not flush
    assert rs.pending                     # ...and must still be pending
    rs.flush()
    snap = rs.snapshot()
    v1 = rs.commit([v0], adds={0: _pay(rng)})
    got = snap.execute([Q.version(v0)])[0].value
    assert set(got) == set(range(20))
    assert rs.pending == [v1]             # the read did not flush v1
    with pytest.raises(RuntimeError):
        rs.get_version(v1)                # wrappers refuse too


def test_snapshot_invalidated_by_full_rebuild():
    """A full build() repartitions chunk storage; a snapshot from before
    must fail loudly rather than read rewritten chunks against stale ids."""
    rng = np.random.default_rng(12)
    rs = RStore(RStoreConfig(capacity=512, batch_size=100, k=3))
    v0 = rs.init_root({k: _pay(rng) for k in range(30)})
    rs.flush()
    snap = rs.snapshot()
    assert len(snap.execute([Q.version(v0)])[0].value) == 30
    rs.commit([v0], adds={0: _pay(rng)})
    rs.get_version(v0)                    # k>1: auto-flush → full rebuild
    with pytest.raises(RuntimeError, match="rebuild"):
        snap.execute([Q.version(v0)])
    assert len(rs.snapshot().execute([Q.version(v0)])[0].value) == 30


def test_snapshot_survives_online_flush():
    """k=1 online flushes only append chunks — old snapshots stay valid."""
    rng = np.random.default_rng(13)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=1))
    v0 = rs.init_root({k: _pay(rng) for k in range(20)})
    snap = rs.snapshot()
    for i in range(5):
        rs.commit([v0], adds={100 + i: _pay(rng)})   # batch_size=1: flushes
    got = snap.execute([Q.version(v0)])[0].value
    assert set(got) == set(range(20))


def test_auto_flush_wrappers_keep_seed_behaviour():
    rng = np.random.default_rng(7)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=100))   # auto_flush=True
    v0 = rs.init_root({k: _pay(rng) for k in range(20)})
    got, stats = rs.get_version(v0)       # implicit flush, like the seed
    assert len(got) == 20
    assert not rs.pending
    assert stats.kvs_queries == 1         # single interleaved multiget now


# -------------------------------------------------------------- satellites
def test_storage_stats_does_not_reset_kvs_counters():
    rng = np.random.default_rng(8)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=8), kvs=kvs)
    vids = _build_branched(rs, rng)
    rs.flush()
    rs.get_version(vids[0])
    before = kvs.stats.snapshot()
    assert before.n_queries > 0
    stats = rs.storage_stats()
    assert stats["stored_chunk_bytes"] > 0
    after = kvs.stats
    assert after.n_queries == before.n_queries        # not polluted
    assert after.bytes_fetched == before.bytes_fetched  # not reset


def test_candidates_range_sorted_lookup_matches_scan():
    rng = np.random.default_rng(9)
    rs = RStore(RStoreConfig(capacity=1024, batch_size=4))
    vids = _build_branched(rs, rng, n_keys=60)
    rs.flush()
    proj = rs.proj
    for lo, hi in [(0, 5), (10, 40), (59, 61), (100, 200), (-5, 2)]:
        expect = sorted(pk for pk in proj.key_chunks if lo <= pk <= hi)
        got = proj.keys_in_range(lo, hi).tolist()
        assert got == expect
        want = proj.candidates(vids[0], expect)
        have = proj.candidates_range(vids[0], lo, hi)
        np.testing.assert_array_equal(want, have)


def test_candidates_batch_matches_single():
    rng = np.random.default_rng(10)
    rs = RStore(RStoreConfig(capacity=1024, batch_size=4))
    vids = _build_branched(rs, rng)
    rs.flush()
    proj = rs.proj
    items = [(vids[i % len(vids)], [int(rng.integers(0, 45))])
             for i in range(10)]
    batch = proj.candidates_batch(items)
    for (vid, pks), ids in zip(items, batch):
        np.testing.assert_array_equal(ids, proj.candidates(vid, pks))
