"""Partitioning algorithms: invariants, paper-claimed orderings, β knob,
sub-chunking (§3.4) and online partitioning (§4)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import datagen
from repro.core.partition import (ALGORITHMS, BFSPartitioner,
                                  BottomUpPartitioner, DeltaBaseline,
                                  DFSPartitioner, ShinglePartitioner,
                                  SingleAddressPartitioner,
                                  SubChunkPartitioner, key_spans,
                                  total_version_span, version_spans)
from repro.core.subchunk import (build_subchunks, build_transformed,
                                 compose_record_to_chunk)

CAP = 4096


def _gen(**kw):
    base = dict(n_versions=80, n_base_records=400, pct_update=0.08,
                branch_prob=0.15, seed=1)
    base.update(kw)
    return datagen.generate(datagen.DatasetSpec(**base))


@pytest.fixture(scope="module")
def tree_graph():
    return _gen()


@pytest.fixture(scope="module")
def chain_graph():
    return _gen(branch_prob=0.0, seed=4)


ALL_PARTITIONERS = ["bottom_up", "shingle", "depth_first", "breadth_first"]


@pytest.mark.parametrize("name", ALL_PARTITIONERS)
def test_partitioning_invariants(tree_graph, name):
    """Every record in exactly one chunk; chunk sizes within C(1+slack)."""
    part = ALGORITHMS[name]().partition(tree_graph, CAP)
    part.validate(tree_graph.store.sizes, CAP)


@pytest.mark.parametrize("name", ALL_PARTITIONERS)
def test_span_lower_bound(tree_graph, name):
    """span(v) ≥ ceil(version_bytes / chunk_limit) — information floor."""
    part = ALGORITHMS[name]().partition(tree_graph, CAP)
    spans = version_spans(tree_graph, part)
    sizes = tree_graph.store.sizes
    for v, m in tree_graph.memberships().items():
        lo = int(np.ceil(sizes[m].sum() / (CAP * 1.25)))
        assert spans[v] >= lo


def test_bottom_up_beats_greedy_and_delta(tree_graph):
    """Fig. 8's headline: BOTTOM-UP < DFS ≤/≈ BFS and ≪ DELTA."""
    bu = total_version_span(tree_graph, BottomUpPartitioner().partition(tree_graph, CAP))
    df = total_version_span(tree_graph, DFSPartitioner().partition(tree_graph, CAP))
    bf = total_version_span(tree_graph, BFSPartitioner().partition(tree_graph, CAP))
    db = DeltaBaseline()
    dl = db.total_version_span(tree_graph, db.partition(tree_graph, CAP))
    assert bu < df
    assert df <= bf
    assert bu < dl


def test_dfs_equals_bfs_on_chains(chain_graph):
    """§3.3: on linear chains the two traversals reduce to the same order."""
    df = DFSPartitioner().partition(chain_graph, CAP)
    bf = BFSPartitioner().partition(chain_graph, CAP)
    np.testing.assert_array_equal(df.record_to_chunk, bf.record_to_chunk)


def test_single_address_span_is_version_size(tree_graph):
    part = SingleAddressPartitioner().partition(tree_graph, CAP)
    spans = version_spans(tree_graph, part)
    for v, m in tree_graph.memberships().items():
        assert spans[v] == len(m)


def test_subchunk_baseline_best_key_span(tree_graph):
    part = SubChunkPartitioner().partition(tree_graph, CAP)
    assert all(s == 1 for s in key_spans(tree_graph, part).values())


def test_beta_degrades_gracefully(tree_graph):
    """§3.2.1 / Fig. 9: smaller β must not *improve* span (quality is
    monotone-ish in β); β=∞ equals a huge finite β."""
    spans = {}
    for beta in [2, 8, 64, 10_000]:
        p = BottomUpPartitioner(beta=beta).partition(tree_graph, CAP)
        p.validate(tree_graph.store.sizes, CAP)
        spans[beta] = total_version_span(tree_graph, p)
    assert spans[2] >= spans[64]
    assert spans[10_000] == spans[64]  # depth never exceeds 64 here? allow equal
    assert spans[8] >= spans[64]


def test_shingle_deterministic(tree_graph):
    p1 = ShinglePartitioner(seed=3).partition(tree_graph, CAP)
    p2 = ShinglePartitioner(seed=3).partition(tree_graph, CAP)
    np.testing.assert_array_equal(p1.record_to_chunk, p2.record_to_chunk)


@given(st.integers(0, 5000))
@settings(max_examples=10, deadline=None)
def test_partitioners_cover_random_graphs(seed):
    g = _gen(n_versions=30, n_base_records=100, branch_prob=0.3,
             merge_prob=0.1, seed=seed)
    for name in ALL_PARTITIONERS:
        part = ALGORITHMS[name]().partition(g, 2048)
        part.validate(g.store.sizes, 2048)


# ------------------------------------------------------------- §3.4 subchunks
@pytest.mark.parametrize("k", [2, 3, 5])
def test_subchunk_groups_valid(tree_graph, k):
    groups = build_subchunks(tree_graph, k)
    keys = tree_graph.store.keys()
    origins = tree_graph.store.origin_versions()
    flat = np.concatenate(groups)
    assert len(flat) == len(tree_graph.store)
    assert len(np.unique(flat)) == len(flat)
    for grp in groups:
        assert 1 <= len(grp) <= k
        assert len(np.unique(keys[grp])) == 1          # one primary key
        # connectivity: every non-base member has an ancestor-origin member
        vs = {int(origins[r]) for r in grp}
        for r in grp[1:]:
            v = tree_graph.tree_parent(int(origins[r]))
            ok = False
            while v is not None:
                if v in vs:
                    ok = True
                    break
                v = tree_graph.tree_parent(v)
            assert ok, "sub-chunk not connected in the version tree"


def test_transformed_tree_spans_match_original(tree_graph):
    """Partitioning the transformed tree must yield exact spans when mapped
    back through record→sub-chunk→chunk composition."""
    groups = build_subchunks(tree_graph, 3)
    tds = build_transformed(tree_graph, groups)
    part = BottomUpPartitioner().partition(tds.tgraph, CAP)
    r2c = compose_record_to_chunk(tds, part.record_to_chunk)
    assert (r2c >= 0).all()
    # each version's record set maps to the same chunks as its sub-chunk set
    for v in tree_graph.versions:
        m = tree_graph.members(v)
        via_rec = np.unique(r2c[m])
        tv = tds.version_alias[v]
        via_sub = np.unique(part.record_to_chunk[tds.tgraph.members(tv)])
        np.testing.assert_array_equal(via_rec, via_sub)


def test_transformed_tree_deduplicates_versions():
    g = _gen(n_versions=40, pct_update=0.02, seed=8)
    groups = build_subchunks(g, 4)
    tds = build_transformed(g, groups)
    # with aggressive grouping some versions collapse into their parents
    assert tds.tgraph.num_versions <= g.num_versions
    assert len(tds.version_alias) == g.num_versions
