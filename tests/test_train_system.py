"""Training substrate: optimizers, RStore-versioned checkpointing (commit/
restore/branch/evolution), crash-restart equivalence, elastic restore,
gradient compression, data-pipeline determinism, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import synthetic_batch
from repro.models.model import build_model, init_params
from repro.serve.engine import Engine
from repro.train import grad_compress
from repro.train.checkpoint import VersionedCheckpointer
from repro.train.optimizer import OptConfig, Optimizer, make_optimizer
from repro.train.train_step import init_state, make_train_step


@pytest.fixture(scope="module")
def small_setup():
    cfg = ARCHS["smollm-360m"].reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32", "remat": "none"})
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    step = jax.jit(make_train_step(model, opt))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    return cfg, model, opt, step, state


# ------------------------------------------------------------- optimizers
def test_adamw_reduces_loss(small_setup):
    cfg, model, opt, step, state = small_setup
    losses = []
    for i in range(8):
        batch = synthetic_batch(cfg, 0, 4, 64)   # same batch → must overfit
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.05


def test_adafactor_reduces_loss():
    cfg = ARCHS["smollm-360m"].reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32", "remat": "none",
                           "optimizer": "adafactor"})
    model = build_model(cfg)
    opt = make_optimizer(cfg, lr=1e-2)
    step = jax.jit(make_train_step(model, opt))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    losses = []
    for _ in range(8):
        state, metrics = step(state, synthetic_batch(cfg, 0, 4, 64))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.05


def test_adafactor_state_is_factored():
    cfg = ARCHS["kimi-k2-1t-a32b"].reduced()
    opt = Optimizer(OptConfig(name="adafactor"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    st = opt.init(params)
    p_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    o_bytes = sum(x.size * 4 for x in jax.tree.leaves(st))
    assert o_bytes < 0.2 * p_bytes     # factored ≪ AdamW's 2× params


# ---------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(small_setup):
    cfg, model, opt, step, state = small_setup
    ckpt = VersionedCheckpointer()
    v0 = ckpt.commit(state, parents=())
    restored = ckpt.restore(v0, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_dedupes_unchanged_blocks(small_setup):
    cfg, model, opt, step, state = small_setup
    ckpt = VersionedCheckpointer(block_bytes=1 << 14)
    v0 = ckpt.commit(state, parents=())
    n0 = len(ckpt.rs.graph.store)
    v1 = ckpt.commit(state, parents=(v0,))        # identical state
    assert len(ckpt.rs.graph.store) == n0         # nothing new stored
    restored = ckpt.restore(v1, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_branching_and_evolution(small_setup):
    cfg, model, opt, step, state = small_setup
    ckpt = VersionedCheckpointer()
    v0 = ckpt.commit(state, parents=())
    sA, _ = step(state, synthetic_batch(cfg, 1, 4, 64))
    sB, _ = step(state, synthetic_batch(cfg, 2, 4, 64))
    vA = ckpt.commit(sA, parents=(v0,), tag="branchA")
    vB = ckpt.commit(sB, parents=(v0,), tag="branchB")
    rA = ckpt.restore(vA, like=state)
    rB = ckpt.restore(vB, like=state)
    la = jax.tree.leaves(rA)[0]
    lb = jax.tree.leaves(rB)[0]
    assert not np.array_equal(np.asarray(la), np.asarray(lb))
    # Q3: the embed table evolved across versions
    some_tensor = sorted(ckpt.meta[v0].keys())[0]
    evo = ckpt.evolution(some_tensor, 0)
    assert len(evo) >= 2


def test_crash_restart_is_bit_identical(small_setup):
    """Training k steps straight == training j, crash, restore, resume."""
    cfg, model, opt, step, state0 = small_setup

    def run(n, s):
        for i in range(n):
            s, _ = step(s, synthetic_batch(cfg, i, 4, 64))
        return s

    straight = run(6, state0)

    ckpt = VersionedCheckpointer()
    mid = run(3, state0)
    v = ckpt.commit(mid, parents=())
    resumed = ckpt.restore(v, like=state0)           # "new process"
    for i in range(3, 6):
        resumed, _ = step(resumed, synthetic_batch(cfg, i, 4, 64))
    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0)


def test_partial_restore_by_prefix(small_setup):
    cfg, model, opt, step, state = small_setup
    ckpt = VersionedCheckpointer()
    v0 = ckpt.commit(state, parents=())
    sub = ckpt.restore_tensors(v0, prefixes=["params/embed"])
    assert len(sub) >= 1
    for k in sub:
        assert k.startswith("params/embed")


# ------------------------------------------------------------ elastic
def test_elastic_restore_to_different_mesh(small_setup):
    import os
    cfg, model, opt, step, state = small_setup
    from repro.launch.mesh import make_debug_mesh
    from repro.train.elastic import restore_for_mesh
    ckpt = VersionedCheckpointer()
    v0 = ckpt.commit(state, parents=())
    mesh = make_debug_mesh(1, 1)
    new_state = restore_for_mesh(ckpt, v0, state, cfg, opt, mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- gradient compression
def test_compress_update_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(0, 0.01, (1000,)).astype(np.float32))
    q, scale = grad_compress.compress_update(u)
    back = grad_compress.decompress_update(q, scale, u.shape, jnp.float32)
    err = float(jnp.max(jnp.abs(back - u)))
    assert err <= float(jnp.max(jnp.abs(u))) / 127 + 1e-8


def test_xor_delta_stats_detects_sparsity():
    rng = np.random.default_rng(1)
    prev = rng.integers(0, 2**32, 65536, dtype=np.uint32)
    new = prev.copy()
    new[:64] ^= 12345                     # change 64 of 65536 words
    st = grad_compress.xor_delta_stats(prev, new)
    assert 0 < st["changed_word_fraction"] < 0.01


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_skip_ahead():
    cfg = ARCHS["smollm-360m"].reduced()
    b1 = synthetic_batch(cfg, 7, 4, 32)
    b2 = synthetic_batch(cfg, 7, 4, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_batch(cfg, 8, 4, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < cfg.vocab_size


# ------------------------------------------------------------------ serving
def test_engine_generation_matches_stepwise(small_setup):
    cfg, model, opt, step, state = small_setup
    eng = Engine(cfg, state["params"], max_len=128)
    batch = {"tokens": synthetic_batch(cfg, 0, 2, 16)["tokens"]}
    toks = eng.generate(batch, steps=5)
    assert toks.shape == (2, 5)
    # manual decode must agree
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, max_len=128))(
        state["params"], batch)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    manual = [cur[:, 0]]
    pos = 16
    dstep = jax.jit(model.decode_step)
    for i in range(4):
        nxt, caches = dstep(state["params"], caches, cur, pos)
        manual.append(nxt)
        cur = nxt[:, None]
        pos += 1
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.stack([np.asarray(m) for m in manual], 1))
