"""Write-path sessions and the sharded Backend protocol: group flushes must
cost one multiput per shard, the ShardedKVS router must be read/write
equivalent to a single InMemoryKVS, session misuse must be loud, and the
satellite fixes (empty-batch stats, device-KVS slot free list, incremental
stored_chunk_bytes) must hold."""
import numpy as np
import pytest

from repro.core import Q, RStore, RStoreConfig
from repro.core.kvs import InMemoryKVS, ShardedDeviceKVS, ShardedKVS


def _pay(rng, n=100):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def _mixed_queries(vids, rng, n=32, n_keys=40):
    qs = []
    for i in range(n):
        v = vids[i % len(vids)]
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.integers(0, n_keys))))
        elif kind == 2:
            lo = int(rng.integers(0, n_keys))
            qs.append(Q.range(v, lo, lo + 10))
        else:
            qs.append(Q.evolution(int(rng.integers(0, n_keys))))
    return qs


def _session_workload(rs, rng, n_versions=64, n_keys=40):
    with rs.writer() as w:
        v = w.init_root({k: _pay(rng) for k in range(n_keys)})
        vids = [v]
        for i in range(n_versions - 1):
            v = w.commit([v], adds={int(rng.integers(0, n_keys)): _pay(rng),
                                    n_keys + i: _pay(rng)})
            vids.append(v)
    return vids


# ----------------------------------------------------------- group commits
def test_64_version_session_is_one_multiput_per_shard():
    """The acceptance criterion: a 64-version WriteSession flush on a
    4-shard ShardedKVS = exactly 4 backend write round trips."""
    rng = np.random.default_rng(0)
    kvs = ShardedKVS([InMemoryKVS() for _ in range(4)])
    rs = RStore(RStoreConfig(capacity=4096, batch_size=10**9), kvs=kvs)
    vids = _session_workload(rs, rng, n_versions=64)
    assert kvs.stats.n_put_queries == 4
    assert [s.stats.n_put_queries for s in kvs.shards] == [1, 1, 1, 1]
    # many more blobs than round trips moved through those 4 multiputs
    assert kvs.stats.n_values_put > 8

    # read sessions through the router: one round trip per shard touched
    snap = rs.snapshot()
    q0 = kvs.stats.n_queries
    res = snap.execute(_mixed_queries(vids, rng))
    read_rts = kvs.stats.n_queries - q0
    assert 1 <= read_rts <= 4
    assert res.batch.kvs_queries == read_rts


def test_single_backend_session_is_one_round_trip():
    rng = np.random.default_rng(1)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=4096, batch_size=10**9), kvs=kvs)
    _session_workload(rs, rng, n_versions=16)
    assert kvs.stats.n_put_queries == 1


def test_sharded_matches_inmemory_backend():
    """Identical workload through ShardedKVS(4) and InMemoryKVS must give
    byte-identical query results (routing is invisible to the engine)."""
    results = []
    for kvs in (InMemoryKVS(), ShardedKVS([InMemoryKVS() for _ in range(4)])):
        rng = np.random.default_rng(7)
        rs = RStore(RStoreConfig(capacity=1024, batch_size=5), kvs=kvs)
        v0 = rs.init_root({k: _pay(rng) for k in range(40)})
        v1 = rs.commit([v0], adds={3: _pay(rng), 40: _pay(rng)}, dels=[7])
        v2 = rs.commit([v0], adds={3: _pay(rng)}, dels=[2])
        v3 = rs.commit([v1, v2], adds={50: _pay(rng)})
        rs.flush()
        qs = _mixed_queries([v0, v1, v2, v3], np.random.default_rng(9))
        results.append([r.value for r in rs.snapshot().execute(qs)])
    assert results[0] == results[1]


def test_sharded_router_roundtrip_and_order():
    kvs = ShardedKVS([InMemoryKVS() for _ in range(3)])
    blobs = {f"k{i}": bytes([i]) * (i + 1) for i in range(30)}
    kvs.multiput(list(blobs.items()))
    assert kvs.multiget(list(blobs)) == list(blobs.values())
    assert all(k in kvs for k in blobs)
    assert "nope" not in kvs
    assert kvs.get("k3") == blobs["k3"]
    assert kvs.total_stored_bytes() == sum(len(v) for v in blobs.values())
    # keys actually spread over the shards
    assert sum(1 for s in kvs.shards if s.total_stored_bytes()) >= 2
    agg = kvs.aggregate_shard_stats()
    assert agg.n_values_put == 30


# ------------------------------------------------------------------ misuse
def test_commit_after_close_raises():
    rng = np.random.default_rng(2)
    rs = RStore(RStoreConfig(batch_size=10**9))
    w = rs.writer()
    w.init_root({0: _pay(rng)})
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.commit([0], adds={1: _pay(rng)})
    w.close()                                # idempotent


def test_overlapping_sessions_raise():
    rng = np.random.default_rng(3)
    rs = RStore(RStoreConfig(batch_size=10**9))
    w = rs.writer()
    with pytest.raises(RuntimeError, match="already open"):
        rs.writer()
    with pytest.raises(RuntimeError, match="already open"):
        rs.init_root({0: _pay(rng)})          # facade wrappers are sessions too
    w.close()
    rs.init_root({0: _pay(rng)})              # fine once closed


def test_session_exception_skips_flush():
    """If the with-body raises, nothing is flushed — staged versions stay
    pending and the next flush picks them up."""
    rng = np.random.default_rng(4)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9), kvs=kvs)
    with pytest.raises(ZeroDivisionError):
        with rs.writer() as w:
            w.init_root({k: _pay(rng) for k in range(10)})
            raise ZeroDivisionError
    assert kvs.stats.n_put_queries == 0
    assert len(rs.pending) == 1
    rs.flush()
    assert kvs.stats.n_put_queries == 1
    assert len(rs.get_version(0)[0]) == 10


def test_read_during_open_session_raises():
    """snapshot()/get_* over versions an open session staged must raise —
    auto-flushing them would split the session's one group commit."""
    rng = np.random.default_rng(14)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9), kvs=kvs)
    with rs.writer() as w:
        v0 = w.init_root({k: _pay(rng) for k in range(10)})
        with pytest.raises(RuntimeError, match="open WriteSession"):
            rs.get_version(v0)
        assert kvs.stats.n_put_queries == 0   # nothing leaked mid-session
    assert kvs.stats.n_put_queries == 1       # the close still group-flushed
    assert len(rs.get_version(v0)[0]) == 10
    # reading the *flushed* state while a writer is open stays legal
    with rs.writer() as w:
        snap = rs.snapshot()
        w.commit([v0], adds={50: _pay(rng)})
        assert len(snap.execute([Q.version(v0)])[0].value) == 10


def test_flush_and_build_during_open_session_raise():
    """Explicit flush()/build() mid-session are the one path that could
    split the group commit silently — they must raise like snapshot()."""
    rng = np.random.default_rng(16)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9), kvs=kvs)
    with rs.writer() as w:
        w.init_root({k: _pay(rng) for k in range(10)})
        with pytest.raises(RuntimeError, match="group commit"):
            rs.flush()
        with pytest.raises(RuntimeError, match="group commit"):
            rs.build()
        assert kvs.stats.n_put_queries == 0
    assert kvs.stats.n_put_queries == 1       # close's own flush still runs


def test_facade_wrappers_keep_delta_store_batching():
    """rs.commit() is a one-commit session but must NOT flush per commit —
    the delta store still batches up to batch_size (seed behaviour)."""
    rng = np.random.default_rng(5)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=4), kvs=kvs)
    v = rs.init_root({k: _pay(rng) for k in range(10)})
    assert rs.pending and kvs.stats.n_put_queries == 0
    for i in range(3):
        v = rs.commit([v], adds={20 + i: _pay(rng)})
    assert not rs.pending                     # 4th staged version flushed
    assert kvs.stats.n_put_queries == 1       # ...as ONE group commit


# ------------------------------------------------- empty-batch stats (satellite)
@pytest.mark.parametrize("make", [
    InMemoryKVS,
    lambda: ShardedKVS([InMemoryKVS(), InMemoryKVS()]),
    lambda: ShardedDeviceKVS(slot_bytes=64, n_slots=8),
])
def test_empty_batches_cost_zero_round_trips(make):
    kvs = make()
    assert kvs.multiget([]) == []
    kvs.multiput([])
    assert kvs.stats.n_queries == 0
    assert kvs.stats.n_put_queries == 0
    assert kvs.stats.n_values == 0 and kvs.stats.n_values_put == 0


def test_all_empty_plan_session_costs_zero_round_trips():
    rng = np.random.default_rng(6)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=2048, batch_size=4), kvs=kvs)
    rs.init_root({k: _pay(rng) for k in range(10)})
    rs.flush()
    snap = rs.snapshot()
    q0 = kvs.stats.n_queries
    res = snap.execute([Q.record(0, 999), Q.evolution(888)])
    assert kvs.stats.n_queries == q0
    assert res.batch.kvs_queries == 0


# ---------------------------------------------- device-KVS free list (satellite)
def test_device_kvs_relocation_reclaims_slots():
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=8)
    kvs.put("a", b"x" * 60)                   # 1 slot
    kvs.put("b", b"y" * 130)                  # 3 slots (spanning)
    high = kvs.high_water_slots
    assert high == 4 and kvs.free_slots == 0
    kvs.put("a", b"x" * 200)                  # grows to 4 slots: relocates
    assert kvs.free_slots == 1                # old single slot reclaimed
    kvs.put("c", b"z" * 10)                   # first-fit reuses the hole
    assert kvs.free_slots == 0
    assert kvs.high_water_slots == high + 4   # no growth for c
    kvs.put("b", b"y" * 40)                   # shrink in place: frees tail
    assert kvs.free_slots == 2
    assert kvs.multiget(["a", "b", "c"]) == [b"x" * 200, b"y" * 40, b"z" * 10]


def test_device_kvs_overwrite_churn_does_not_leak():
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=8)
    rng = np.random.default_rng(8)
    blobs = {}
    for step in range(120):
        key = f"k{step % 10}"
        blobs[key] = _pay(rng, int(rng.integers(1, 260)))
        kvs.put(key, blobs[key])
    assert kvs.multiget(list(blobs)) == list(blobs.values())
    # bounded: never more slots than worst-case live + reclaimable holes
    assert kvs.high_water_slots - kvs.free_slots <= 10 * 5


def test_device_kvs_growing_value_reuses_coalesced_extents():
    """A repeatedly-growing value must not strand its old extents: released
    neighbours coalesce (and trim the high-water mark), so the footprint
    stays near the live size instead of doubling per relocation."""
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=4)
    for i in range(1, 30):
        kvs.put("g", b"x" * (64 * i))
    assert kvs.high_water_slots - kvs.free_slots == 29      # live slots only
    assert kvs.high_water_slots <= 2 * 29
    assert kvs.get("g") == b"x" * (64 * 29)


def test_device_kvs_multiput_one_round_trip():
    kvs = ShardedDeviceKVS(slot_bytes=64, n_slots=8)
    rng = np.random.default_rng(9)
    items = [(f"k{i}", _pay(rng, int(rng.integers(1, 200)))) for i in range(15)]
    kvs.multiput(items)
    assert kvs.stats.n_put_queries == 1
    assert kvs.stats.n_values_put == 15
    assert kvs.multiget([k for k, _ in items]) == [v for _, v in items]


# --------------------------------------------- mesh-aware shard placement
def test_make_sharded_backend_mesh_placement():
    """Each shard's table must land on its own device slice; the store must
    stay exact through the device-sharded router."""
    from repro.launch.mesh import make_debug_mesh, make_sharded_backend

    mesh = make_debug_mesh(4, 2)                  # 8 host devices (conftest)
    kvs = make_sharded_backend(n_shards=4, mesh=mesh, slot_bytes=1024,
                               n_slots=16)
    assert len(kvs.shards) == 4
    slices = [tuple(d.id for d in s.mesh.devices.reshape(-1))
              for s in kvs.shards]
    assert len(set(sum(slices, ()))) == 8         # disjoint, covers the mesh

    rng = np.random.default_rng(13)
    rs = RStore(RStoreConfig(algorithm="depth_first", capacity=1024,
                             batch_size=10**9), kvs=kvs)
    vids = _session_workload(rs, rng, n_versions=8, n_keys=20)
    assert kvs.stats.n_put_queries == sum(
        1 for s in kvs.shards if s.stats.n_put_queries)
    for v in (vids[0], vids[-1]):
        got = rs.get_version(v)[0]
        m = rs.graph.members(v)
        keys = rs.graph.store.keys()
        assert got == {int(keys[r]): rs.graph.store.payload(int(r))
                       for r in m}


def test_make_sharded_backend_more_shards_than_devices():
    from repro.launch.mesh import make_debug_mesh, make_sharded_backend

    kvs = make_sharded_backend(n_shards=4, mesh=make_debug_mesh(1, 2),
                               slot_bytes=256, n_slots=4)
    items = [(f"k{i}", bytes([i]) * 40) for i in range(12)]
    kvs.multiput(items)
    assert kvs.multiget([k for k, _ in items]) == [v for _, v in items]


def test_make_sharded_backend_meshless():
    from repro.launch.mesh import make_sharded_backend

    kvs = make_sharded_backend(n_shards=3, mesh=None, slot_bytes=256,
                               n_slots=4)
    kvs.multiput([("a", b"x" * 10), ("b", b"y" * 300)])
    assert kvs.multiget(["b", "a"]) == [b"y" * 300, b"x" * 10]


# ------------------------------------- incremental storage stats (satellite)
@pytest.mark.parametrize("k", [1, 3])
def test_stored_chunk_bytes_tracked_without_fetch(k):
    rng = np.random.default_rng(10)
    kvs = InMemoryKVS()
    rs = RStore(RStoreConfig(capacity=1024, batch_size=3, k=k), kvs=kvs)
    v = rs.init_root({kk: _pay(rng) for kk in range(30)})
    for i in range(5):
        v = rs.commit([v], adds={40 + i: _pay(rng)})
    rs.flush()
    q0 = kvs.stats.n_queries
    stats = rs.storage_stats()
    assert kvs.stats.n_queries == q0          # no sizing fetch
    actual = sum(len(kvs._d[f"chunk/{c}"]) for c in range(rs.n_chunks))
    assert stats["stored_chunk_bytes"] == actual


# ------------------------------------------------- checkpointer group commits
def test_checkpointer_commit_many_single_group_flush():
    from repro.train.checkpoint import VersionedCheckpointer

    kvs = ShardedKVS([InMemoryKVS() for _ in range(4)])
    rs = RStore(RStoreConfig(capacity=1 << 16, batch_size=10**9), kvs=kvs)
    ck = VersionedCheckpointer(store=rs, block_bytes=512)
    rng = np.random.default_rng(12)
    states = [{"w": rng.normal(size=(32, 8)).astype(np.float32)}]
    for _ in range(3):
        states.append({"w": states[-1]["w"] + 1.0})
    vids = ck.commit_many(states)
    assert vids == [0, 1, 2, 3]
    # chain parentage: each version hangs off the previous one
    assert all(rs.graph.parents[v] == (v - 1,) for v in vids[1:])
    # the whole chain reached the backend as ONE multiput per shard touched
    assert all(s.stats.n_put_queries <= 1 for s in kvs.shards)
    assert kvs.stats.n_put_queries == sum(
        s.stats.n_put_queries for s in kvs.shards)
    # no-op: must not open a writer or flush pending state
    rts = kvs.stats.n_put_queries
    assert ck.commit_many([]) == []
    assert kvs.stats.n_put_queries == rts
    got = ck.restore(vids[-1])
    np.testing.assert_array_equal(got["w"], states[-1]["w"])


# ------------------------------------------------ columnar commit semantics
def test_merge_parents_sharing_exclusive_key_pull_once():
    """Two merge parents both exclusively holding a pk must contribute ONE
    live record (earlier parent wins) — the seed pulled both, creating a
    phantom duplicate that dels could not fully remove."""
    rng = np.random.default_rng(15)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9))
    v0 = rs.init_root({k: _pay(rng) for k in range(3)})
    p1 = _pay(rng)
    v1 = rs.commit([v0], adds={10: p1})
    v2 = rs.commit([v0], adds={10: _pay(rng)})
    v3 = rs.commit([v0, v1, v2], adds={})
    keys = rs.graph.store.keys()[rs.graph.members(v3)]
    assert sorted(keys.tolist()) == [0, 1, 2, 10]     # pk 10 exactly once
    assert rs.get_version(v3)[0][10] == p1            # earlier parent wins
    v4 = rs.commit([v3], adds={}, dels=[10])
    assert sorted(rs.get_version(v4)[0]) == [0, 1, 2]  # fully deleted


def test_columnar_commit_error_semantics_match_seed():
    rng = np.random.default_rng(11)
    rs = RStore(RStoreConfig(capacity=2048, batch_size=10**9))
    v0 = rs.init_root({k: _pay(rng) for k in range(10)})
    with pytest.raises(KeyError, match="absent"):
        rs.commit([v0], adds={}, dels=[999])
    with pytest.raises(ValueError, match="both added and deleted"):
        rs.commit([v0], adds={5: _pay(rng)}, dels=[5])
    with pytest.raises(ValueError, match="out of range"):
        rs.commit([v0], adds={-3: _pay(rng)})
    # failed wrapper commits must not wedge the writer slot
    v1 = rs.commit([v0], adds={10: _pay(rng)}, dels=[0])
    assert sorted(rs.get_version(v1)[0]) == list(range(1, 11))
