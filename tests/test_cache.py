"""Chunk cache subsystem: segmented-LRU eviction, cost-model admission,
write-through/delete coherence, layout-epoch invalidation, cache-aware read
path (0 round trips warm), evolution prefetch, and stats wiring."""
import numpy as np
import pytest

from repro.core import (CachingKVS, InMemoryKVS, KVSStats, Q, RStore,
                        RStoreConfig, ShardedKVS, keep_last)
from repro.core.cache import ENTRY_OVERHEAD
from repro.core.costmodel import fetch_seconds
from repro.core.replica import FaultInjectingKVS, ReplicatedKVS


def _cache(cache_bytes=1 << 16, **kw):
    inner = InMemoryKVS()
    return CachingKVS(inner, cache_bytes=cache_bytes, **kw), inner


# -------------------------------------------------------------- empty batches
def test_empty_batch_guard_no_round_trip_no_stats():
    """PR-2 convention: empty multiget/multiput/multidelete are free — no
    backend call, stats untouched."""
    c, inner = _cache()
    assert c.multiget([]) == []
    c.multiput([])
    c.multidelete([])
    for f in KVSStats._FIELDS:
        assert getattr(c.stats, f) == 0
        assert getattr(inner.stats, f) == 0


# ------------------------------------------------------------ hit/miss basics
def test_miss_then_hit_round_trip_accounting():
    c, inner = _cache()
    inner.put("k", b"hello")
    inner.stats.reset()

    assert c.get("k") == b"hello"              # cold: 1 inner round trip
    assert (c.stats.n_queries, c.stats.n_cache_misses) == (1, 1)
    assert c.stats.n_cache_hits == 0

    assert c.get("k") == b"hello"              # warm: 0 inner round trips
    assert c.stats.n_queries == 1              # unchanged
    assert c.stats.n_cache_hits == 1
    assert c.stats.bytes_served_from_cache == len(b"hello")


def test_partial_hit_issues_one_multiget_for_misses_only():
    c, inner = _cache()
    inner.multiput([(f"k{i}", bytes([i]) * 8) for i in range(6)])
    inner.stats.reset()
    c.multiget(["k0", "k1", "k2"])             # warm 3 of 6
    q0, v0 = c.stats.n_queries, inner.stats.n_values
    got = c.multiget([f"k{i}" for i in range(6)])
    assert got == [bytes([i]) * 8 for i in range(6)]   # order preserved
    assert c.stats.n_queries - q0 == 1         # ONE fetch for the misses
    assert inner.stats.n_values - v0 == 3      # only k3..k5 crossed the wire


def test_missing_key_raises_data_level_keyerror():
    c, _ = _cache()
    with pytest.raises(KeyError) as ei:
        c.multiget(["gone/7"])
    assert "gone/7" in str(ei.value)


# ------------------------------------------------------------- coherence
def test_write_through_updates_cached_entry():
    c, inner = _cache()
    c.put("k", b"old")
    assert c.get("k") == b"old"                # cached now
    c.put("k", b"new")                         # write-through
    q0 = c.stats.n_queries
    assert c.get("k") == b"new"                # served fresh, from cache
    assert c.stats.n_queries == q0
    assert inner.get("k") == b"new"


def test_multidelete_invalidates_before_forwarding():
    c, inner = _cache()
    c.put("k", b"v")
    c.get("k")
    c.multidelete(["k"])
    assert "k" not in inner._d
    with pytest.raises(KeyError):              # not served from a stale cache
        c.get("k")


def test_writes_do_not_pollute_read_cache():
    """multiput of previously-uncached keys must not admit them — the cache
    holds what was *read*, not everything ever written."""
    c, _ = _cache()
    c.multiput([(f"w{i}", b"x" * 32) for i in range(10)])
    assert c.n_entries == 0
    c.get("w3")                                # reading it admits it
    assert c.n_entries == 1


def test_layout_epoch_hook_invalidates_touched_and_all():
    c, inner = _cache()
    inner.multiput([("a", b"1"), ("b", b"2"), ("c", b"3")])
    c.multiget(["a", "b", "c"])
    c.on_layout_epoch(1, ["a", "b"])
    assert c.layout_epoch == 1
    assert c.n_entries == 1                    # only "c" survives
    c.on_layout_epoch(2)                       # None -> flush everything
    assert c.n_entries == 0 and c.cached_bytes == 0


def test_contains_checks_cache_then_inner():
    c, inner = _cache()
    inner.put("k", b"v")
    assert "k" in c and "nope" not in c
    c.get("k")
    assert "k" in c


def test_scan_forwards_without_admitting():
    c, inner = _cache()
    inner.multiput([(f"k{i}", bytes([i])) for i in range(5)])
    assert dict(c.scan()) == dict(inner.scan())
    assert c.n_entries == 0                    # a scan must not flush the hot set


# --------------------------------------------------- budget / eviction / SLRU
def test_budget_never_exceeded_and_lru_evicts():
    val = b"x" * 100
    charge = len(val) + 2 + ENTRY_OVERHEAD     # 2-char keys
    c, inner = _cache(cache_bytes=charge * 4, always_admit_bytes=1 << 20)
    inner.multiput([(f"k{i}", val) for i in range(10)])
    for i in range(10):
        c.get(f"k{i}")
        assert c.cached_bytes <= c.cache_bytes
    assert c.n_entries == 4
    assert c.n_evictions == 6
    # the survivors are the most recently touched
    q0 = c.stats.n_queries
    c.multiget(["k6", "k7", "k8", "k9"])
    assert c.stats.n_queries == q0


def test_probation_promotion_protects_rereferenced_entries():
    """SLRU: one re-reference promotes to protected, so a scan of cold keys
    can't evict the hot set (probation is evicted first)."""
    val = b"x" * 100
    charge = len(val) + 2 + ENTRY_OVERHEAD
    c, inner = _cache(cache_bytes=charge * 4, always_admit_bytes=1 << 20)
    inner.multiput([(f"k{i}", val) for i in range(8)])
    c.multiget(["k0", "k1"])
    c.multiget(["k0", "k1"])                   # promote to protected
    rep = c.cache_report()
    assert rep["n_protected"] == 2
    c.multiget(["k2", "k3", "k4", "k5"])       # cold wave through probation
    q0 = c.stats.n_queries
    c.multiget(["k0", "k1"])                   # hot pair survived the wave
    assert c.stats.n_queries == q0


def test_protected_segment_demotes_over_share():
    val = b"x" * 100
    charge = len(val) + 2 + ENTRY_OVERHEAD
    c, inner = _cache(cache_bytes=charge * 10, protected_frac=0.3,
                      always_admit_bytes=1 << 20)
    inner.multiput([(f"k{i}", val) for i in range(10)])
    for i in range(10):
        c.get(f"k{i}")
        c.get(f"k{i}")                         # promote every entry
    rep = c.cache_report()
    # protected obeys its share of the budget; the rest demoted to probation
    assert rep["n_protected"] <= 3
    assert rep["n_probation"] + rep["n_protected"] == c.n_entries
    assert c.cached_bytes <= c.cache_bytes


# ----------------------------------------------------- cost-model admission
def test_admission_rejects_cold_big_chunk_over_hot_small_ones():
    """Forced eviction: one big chunk must NOT displace many small ones —
    per-query overhead makes the small set's re-fetch cost dominate."""
    small = b"s" * 200                         # re-fetch ≈ per_query_s each
    c, inner = _cache(cache_bytes=6000, always_admit_bytes=100)
    inner.multiput([(f"k{i}", small) for i in range(20)])
    inner.put("big", b"B" * 5000)
    for i in range(20):                        # fill the budget with small hot
        c.get(f"k{i}")
    n0 = c.n_entries
    assert c.get("big") == b"B" * 5000         # served, but...
    assert c.n_admit_rejected >= 1             # ...not admitted
    assert c.n_entries == n0
    # the cost model agrees: one 5000 B fetch is cheaper than re-fetching
    # the ~19 victims it would displace
    assert fetch_seconds(1, 5000) < 19 * fetch_seconds(1, 200)


def test_admission_accepts_when_refetch_cost_beats_victims():
    """A big chunk whose transfer time dwarfs the single tiny victim's
    re-fetch cost IS admitted."""
    c, inner = _cache(cache_bytes=1 << 20, always_admit_bytes=100)
    inner.put("tiny", b"t" * 150)
    inner.put("big", b"B" * ((1 << 20) - 200))
    c.get("tiny")
    c.get("big")                               # evicts tiny, admitted
    assert c.n_entries == 1
    q0 = c.stats.n_queries
    c.get("big")
    assert c.stats.n_queries == q0


def test_tiny_blobs_always_admitted():
    """Chunk-map-sized blobs bypass the admission comparison."""
    c, inner = _cache(cache_bytes=4096, always_admit_bytes=512)
    inner.multiput([("big0", b"B" * 1800), ("big1", b"B" * 1800),
                    ("map", b"m" * 300)])
    c.get("big0")
    c.get("big1")                              # budget now nearly full
    c.get("map")                               # tiny: admitted regardless
    q0 = c.stats.n_queries
    c.get("map")
    assert c.stats.n_queries == q0
    assert c.n_admit_rejected == 0


def test_value_larger_than_budget_never_admitted():
    c, inner = _cache(cache_bytes=256)
    inner.put("huge", b"H" * 1024)
    assert c.get("huge") == b"H" * 1024
    assert c.n_entries == 0 and c.n_admit_rejected == 1


# ----------------------------------------------------- RStore integration
def _store(cached=True, cache_bytes=8 << 20, n_shards=4):
    inner = ShardedKVS([InMemoryKVS() for _ in range(n_shards)])
    kvs = CachingKVS(inner, cache_bytes=cache_bytes) if cached else inner
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=1024,
                             batch_size=4), kvs=kvs)
    return rs, kvs


def _drive(rs, seed=5, n_commits=10):
    rng = np.random.default_rng(seed)

    def pay():
        return rng.integers(0, 256, 64, dtype=np.uint8).tobytes()

    vids = [rs.init_root({pk: pay() for pk in range(16)})]
    for _ in range(n_commits):
        adds = {int(k): pay() for k in rng.integers(0, 32, 6)}
        vids.append(rs.commit([vids[-1]], adds=adds))
    rs.flush()
    return vids


def test_warm_execute_zero_round_trips_byte_identical():
    rs, kvs = _store(cached=True)
    rs0, _ = _store(cached=False)
    vids, vids0 = _drive(rs), _drive(rs0)
    assert vids == vids0
    qs = [Q.version(vids[-1]), Q.record(vids[-1], 3),
          Q.range(vids[-1], 0, 15), Q.evolution(3)]
    snap, snap0 = rs.snapshot(), rs0.snapshot()
    cold, ref = snap.execute(qs), snap0.execute(qs)
    assert cold.batch.kvs_queries == ref.batch.kvs_queries   # cold == uncached
    warm = snap.execute(qs)
    assert warm.batch.kvs_queries == 0                       # fully warm
    assert warm.batch.cache_hits > 0
    assert warm.batch.bytes_from_cache > 0
    assert [r.value for r in warm] == [r.value for r in ref]


def test_prefetch_evolution_warms_exactly_what_the_query_needs():
    rs, kvs = _store(cached=True)
    _drive(rs)
    snap = rs.snapshot()
    rep = snap.prefetch_evolution(3)
    assert rep["cache"] == 1 and rep["warmed_keys"] > 0
    res = snap.execute([Q.evolution(3)])
    assert res.batch.kvs_queries == 0          # lineage fully warmed
    # uncached snapshot reports a no-op instead of failing
    rs0, _ = _store(cached=False)
    _drive(rs0)
    assert rs0.snapshot().prefetch_evolution(3)["cache"] == 0


def test_compaction_invalidates_cache_and_results_stay_identical():
    rs, kvs = _store(cached=True)
    rs0, _ = _store(cached=False)
    vids, _ = _drive(rs), _drive(rs0)
    keep = vids[-4:]
    snap = rs.snapshot()
    snap.execute([Q.version(v) for v in keep])  # warm the cache
    for store in (rs, rs0):
        store.retain(keep_last(4))
        store.compact()
    assert kvs.layout_epoch > 0                # hook fired
    a = rs.snapshot().execute([Q.version(v) for v in keep])
    b = rs0.snapshot().execute([Q.version(v) for v in keep])
    assert [r.value for r in a] == [r.value for r in b]


def test_cache_stats_and_storage_stats_report():
    rs, kvs = _store(cached=True)
    vids = _drive(rs)
    assert rs.cache_stats()["n_cache_misses"] == 0
    rs.get_version(vids[-1])
    rs.get_version(vids[-1])
    rep = rs.cache_stats()
    assert rep["n_cache_hits"] > 0 and 0 < rep["hit_rate"] < 1
    assert rep["cached_bytes"] <= rep["cache_bytes"]
    assert rs.storage_stats()["cache"]["n_cache_hits"] == rep["n_cache_hits"]
    # uncached store: no cache section, cache_stats() is None
    rs0, _ = _store(cached=False)
    _drive(rs0)
    assert rs0.cache_stats() is None
    assert "cache" not in rs0.storage_stats()


def test_cache_over_replicated_backend_survives_replica_death():
    groups = [ReplicatedKVS([FaultInjectingKVS(InMemoryKVS(), seed=i * 2 + r)
                             for r in range(2)], write_quorum=1)
              for i in range(2)]
    kvs = CachingKVS(ShardedKVS(groups), cache_bytes=8 << 20)
    rs = RStore(RStoreConfig(capacity=1024, batch_size=4), kvs=kvs)
    rs0, _ = _store(cached=False, n_shards=2)
    vids, _ = _drive(rs), _drive(rs0)
    for g in groups:
        g.replicas[0].kill()
    got, _ = rs.get_version(vids[-1])
    want, _ = rs0.get_version(vids[-1])
    assert got == want                         # failover below the cache
    warm, _ = rs.get_version(vids[-1])
    assert warm == want


def test_make_sharded_backend_cache_bytes_wiring():
    from repro.launch.mesh import make_sharded_backend

    kvs = make_sharded_backend(n_shards=2, cache_bytes=1 << 20,
                               cache_kw={"always_admit_bytes": 256})
    assert getattr(kvs, "is_cache", False)
    assert kvs.cache_bytes == 1 << 20 and kvs.always_admit_bytes == 256
    kvs.multiput([(f"k{i}", bytes([i]) * 16) for i in range(8)])
    assert kvs.multiget(["k3", "k6"]) == [b"\x03" * 16, b"\x06" * 16]
    q0 = kvs.stats.n_queries
    kvs.multiget(["k3", "k6"])                 # warm now
    assert kvs.stats.n_queries == q0
    # default stays uncached (back-compat)
    assert not getattr(make_sharded_backend(n_shards=2), "is_cache", False)
