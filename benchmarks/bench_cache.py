"""Chunk cache: warm-read round-trip elimination and epoch coherence.

The online counterpart of the paper's offline layout tuning: a
cost-model-driven read cache (:class:`repro.core.cache.CachingKVS`) over the
sharded backend, measured on the mixed-64 query batch (version / record /
range / evolution mix).

Asserts the acceptance criteria, which are also the CI smoke gates:

1. a FULLY WARM cache serves the mixed-64 batch with 0 backend read round
   trips and ≥5x lower simulated seconds (§2.3 Cassandra-like model);
2. a COLD cache costs exactly the same read round trips as an uncached run
   of the identical store — the cache layer adds no traffic of its own;
3. after a ``retain(keep_last(k))`` + ``compact()`` pass invalidates the
   touched chunks, reads through the (previously warm) cache stay
   byte-identical to fresh uncached reads.

Also reports ``prefetch_evolution``: after the VersionGraph-path warm-up, an
evolution query runs with 0 backend read round trips.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CachingKVS, InMemoryKVS, KVSStats, Q, RStore,
                        RStoreConfig, ShardedKVS, keep_last)
from repro.core.costmodel import BANDWIDTH_BPS, PER_QUERY_S

from .common import emit, save_json

N_SHARDS = 4
CACHE_BYTES = 64 << 20


def _make_store(cached: bool, capacity: int, batch: int):
    inner = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    kvs = CachingKVS(inner, cache_bytes=CACHE_BYTES) if cached else inner
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=batch), kvs=kvs)
    return rs, kvs


def _ingest_chain(rs, rng, n_versions, n_keys, rec_size):
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    v = rs.init_root({k: pay() for k in range(n_keys)})
    vids = [v]
    for _ in range(n_versions - 1):
        ks = rng.choice(n_keys, size=2, replace=False)
        v = rs.commit([v], adds={int(k): pay() for k in ks})
        vids.append(v)
    rs.flush()
    return vids


def _mixed_queries(vids, n_keys, rng, n=64):
    qs = []
    for i in range(n):
        v = vids[i % len(vids)]
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.integers(0, n_keys))))
        elif kind == 2:
            lo = int(rng.integers(0, n_keys))
            qs.append(Q.range(v, lo, lo + n_keys // 8))
        else:
            qs.append(Q.evolution(int(rng.integers(0, n_keys))))
    return qs


def _sim(batch) -> float:
    return KVSStats(n_queries=batch.kvs_queries,
                    bytes_fetched=batch.bytes_fetched).simulated_seconds(
                        PER_QUERY_S, BANDWIDTH_BPS)


def run(smoke: bool = False):
    n_versions = 24 if smoke else 256
    n_keys = 24 if smoke else 96
    rec_size = 128 if smoke else 512
    capacity = 1024 if smoke else 8192
    batch = 8 if smoke else 32

    # identically-driven stores: cached subject, uncached reference
    rs, kvs = _make_store(True, capacity, batch)
    rs0, _ = _make_store(False, capacity, batch)
    vids = _ingest_chain(rs, np.random.default_rng(41), n_versions, n_keys,
                         rec_size)
    vids0 = _ingest_chain(rs0, np.random.default_rng(41), n_versions, n_keys,
                          rec_size)
    assert vids == vids0
    queries = _mixed_queries(vids[-16:], n_keys, np.random.default_rng(42))
    snap, snap0 = rs.snapshot(), rs0.snapshot()

    # ---- gate 2: cold cache == uncached round trips -----------------------
    ref = snap0.execute(queries)
    cold = snap.execute(queries)
    assert cold.batch.kvs_queries == ref.batch.kvs_queries, \
        (cold.batch.kvs_queries, ref.batch.kvs_queries)
    for a, b in zip(cold, ref):
        assert a.value == b.value, f"cold result diverged for {a.query}"

    # ---- gate 1: warm cache = 0 read round trips, >=5x lower sim seconds --
    warm = snap.execute(queries)
    assert warm.batch.kvs_queries == 0, warm.batch.kvs_queries
    assert warm.batch.cache_hits > 0
    for a, b in zip(warm, ref):
        assert a.value == b.value, f"warm result diverged for {a.query}"
    sim_cold, sim_warm = _sim(cold.batch), _sim(warm.batch)
    assert sim_warm == 0.0                      # zero backend traffic
    # >=5x criterion: with 0 round trips and 0 bytes the warm batch costs 0
    # simulated seconds, so any 5x bound holds with infinite headroom
    assert sim_cold >= 5 * sim_warm and sim_cold > 0

    # ---- prefetch_evolution: graph-path warm-up -> 0-RT evolution ---------
    rs_p, _ = _make_store(True, capacity, batch)
    _ingest_chain(rs_p, np.random.default_rng(41), n_versions, n_keys,
                  rec_size)
    snap_p = rs_p.snapshot()
    pk = int(np.random.default_rng(43).integers(0, n_keys))
    pre = snap_p.prefetch_evolution(pk)
    evo = snap_p.execute([Q.evolution(pk)])
    assert evo.batch.kvs_queries == 0, evo.batch.kvs_queries
    assert evo[0].value == rs0.get_evolution(pk)[0]

    # ---- gate 3: retention + compaction invalidate; warm reads stay exact -
    keep = max(4, n_versions // 4)
    for store in (rs, rs0):
        store.retain(keep_last(keep))
        store.compact()
    inv_before = kvs.cache_report()["n_invalidations"]
    assert inv_before > 0, "compaction pass invalidated nothing"
    retained = vids[-keep:]
    post = rs.snapshot().execute([Q.version(v) for v in retained])
    post0 = rs0.snapshot().execute([Q.version(v) for v in retained])
    for a, b in zip(post, post0):
        assert a.value == b.value, "post-compaction cached read diverged"

    rep = rs.cache_stats()
    out = {
        "n_versions": n_versions, "n_shards": N_SHARDS,
        "cache_bytes": CACHE_BYTES,
        "mixed64_read_round_trips": {"uncached": ref.batch.kvs_queries,
                                     "cold": cold.batch.kvs_queries,
                                     "warm": warm.batch.kvs_queries},
        "mixed64_simulated_s": {"cold": sim_cold, "warm": sim_warm,
                                "speedup": "inf (0 backend traffic)"},
        "warm_batch": {"cache_hits": warm.batch.cache_hits,
                       "bytes_from_cache": warm.batch.bytes_from_cache},
        "prefetch_evolution": {**pre,
                               "query_round_trips": evo.batch.kvs_queries},
        "post_compaction": {"invalidations": rep["n_invalidations"],
                            "byte_identical": True},
        "cache_report": rep,
    }
    emit("cache/warm_round_trips", 0.0,
         f"uncached={ref.batch.kvs_queries} cold={cold.batch.kvs_queries} "
         f"warm=0 sim_ms {sim_cold*1e3:.2f}->0.00 (>=5x with inf headroom)")
    emit("cache/prefetch_evolution", 0.0,
         f"warmed_keys={pre['warmed_keys']} then evolution rts=0")
    emit("cache/compaction_coherence", 0.0,
         f"invalidations={rep['n_invalidations']} hit_rate="
         f"{rep['hit_rate']:.2f} post-compact byte-identical")
    save_json("bench_cache", out)
    return out


if __name__ == "__main__":
    run()
