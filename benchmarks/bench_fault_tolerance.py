"""Fault tolerance: degraded-read overhead and shard recovery cost.

The availability workload the replication layer exists for: a sharded,
R=2-replicated backend serving a 64-query mixed batch when one replica of
every shard group is killed mid-workload.  Measures, healthy vs degraded vs
recovered: router read round trips, per-group failover hops, and the
simulated read seconds (§2.3 Cassandra-like model, plus the deterministic
retry backoff the group would have slept).

Asserts the acceptance criteria — the degraded batch returns byte-identical
results, at most ONE extra read round trip per failed-over shard batch
(and ZERO extra on the next batch: a hard-down replica is skipped, not
re-probed), writes keep landing at quorum 1 while degraded — and the
recovery contract: ``RecoveryManager.rebuild`` restores each lost replica
in O(1) round trips per surviving peer (one survivor scan + ≤3 ops on the
target), after which reads are served by the rebuilt replica again.
Running this under CI is the degraded-mode regression gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (FaultInjectingKVS, InMemoryKVS, KVSStats, Q,
                        RecoveryManager, ReplicatedKVS, RStore, RStoreConfig,
                        ShardedKVS)

from .common import emit, save_json

N_SHARDS = 4
R = 2
PER_QUERY_S = 5e-4
BANDWIDTH = 200e6


def _make_backend():
    groups = [
        ReplicatedKVS([FaultInjectingKVS(InMemoryKVS(), seed=1000 + i * R + r)
                       for r in range(R)], write_quorum=1)
        for i in range(N_SHARDS)]
    return ShardedKVS(groups), groups


def _ingest_chain(rs, rng, n_versions, n_keys, rec_size):
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    v = rs.init_root({k: pay() for k in range(n_keys)})
    vids = [v]
    for _ in range(n_versions - 1):
        ks = rng.choice(n_keys, size=2, replace=False)
        v = rs.commit([v], adds={int(k): pay() for k in ks})
        vids.append(v)
    rs.flush()
    return vids


def _mixed_queries(vids, n_keys, rng, n=64):
    qs = []
    for i in range(n):
        v = vids[i % len(vids)]
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.integers(0, n_keys))))
        elif kind == 2:
            lo = int(rng.integers(0, n_keys))
            qs.append(Q.range(v, lo, lo + n_keys // 8))
        else:
            qs.append(Q.evolution(int(rng.integers(0, n_keys))))
    return qs


def _timed_batch(kvs, groups, snap, queries):
    """Execute a batch; return (results, router_read_rts, group_failover
    hops this batch, simulated seconds incl. retry backoff)."""
    s0 = kvs.stats.snapshot()
    f0 = [g.stats.n_failovers for g in groups]
    b0 = sum(g.stats.simulated_backoff_seconds for g in groups)
    res = snap.execute(queries)
    d = KVSStats(n_queries=kvs.stats.n_queries - s0.n_queries,
                 bytes_fetched=kvs.stats.bytes_fetched - s0.bytes_fetched)
    hops = [g.stats.n_failovers - f for g, f in zip(groups, f0)]
    backoff = sum(g.stats.simulated_backoff_seconds for g in groups) - b0
    sim = (d.simulated_seconds(PER_QUERY_S, BANDWIDTH)
           + sum(hops) * PER_QUERY_S + backoff)
    return res, d.n_queries, hops, sim


def run(smoke: bool = False):
    n_versions = 24 if smoke else 256
    n_keys = 24 if smoke else 96
    rec_size = 128 if smoke else 512
    capacity = 1024 if smoke else 8192
    batch = 8 if smoke else 32

    kvs, groups = _make_backend()
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=batch), kvs=kvs)
    rng = np.random.default_rng(41)
    vids = _ingest_chain(rs, rng, n_versions, n_keys, rec_size)
    queries = _mixed_queries(vids[-16:], n_keys, np.random.default_rng(42))
    snap = rs.snapshot()

    # ---- healthy baseline -------------------------------------------------
    res_healthy, rts_healthy, hops, sim_healthy = _timed_batch(
        kvs, groups, snap, queries)
    assert sum(hops) == 0, f"healthy run failed over: {hops}"

    # ---- kill one replica of every shard group mid-workload ---------------
    for g in groups:
        g.replicas[0].kill()
    res_degraded, rts_degraded, hops1, sim_degraded = _timed_batch(
        kvs, groups, snap, queries)

    for r0, r1 in zip(res_healthy, res_degraded):
        assert r0.value == r1.value, f"degraded result diverged for {r0.query}"
    # ≤ 1 extra read round trip per failed-over shard batch
    assert all(h <= 1 for h in hops1), f"failover hops per group: {hops1}"
    assert sum(hops1) >= 1, "nothing failed over despite the kill"
    assert rts_degraded == rts_healthy, (rts_degraded, rts_healthy)

    # next degraded batch: the dead replica is skipped at zero extra cost
    res_again, _, hops2, _ = _timed_batch(kvs, groups, snap, queries)
    assert sum(hops2) == 0, f"re-probed a known-down replica: {hops2}"
    for r0, r1 in zip(res_healthy, res_again):
        assert r0.value == r1.value

    # writes keep landing while degraded (quorum 1 of 2)
    v = vids[-1]
    with rs.writer() as w:
        for _ in range(4):
            k = int(rng.integers(0, n_keys))
            v = w.commit([v], adds={k: rng.integers(
                0, 256, rec_size, dtype=np.uint8).tobytes()})
            vids.append(v)
    got, _ = rs.get_version(v)
    assert len(got) == n_keys

    # ---- recovery ---------------------------------------------------------
    for g in groups:
        g.replicas[0].revive()
    rm = RecoveryManager(kvs)
    t0 = time.perf_counter()
    reports = [rm.rebuild(0, shard=i) for i in range(N_SHARDS)]
    recovery_wall = time.perf_counter() - t0
    # O(1) round trips per surviving peer: one survivor scan + ≤3 target ops
    assert all(r.read_round_trips == 2 for r in reports), reports
    assert all(r.round_trips <= 4 for r in reports), reports
    assert all(g.preferred == 0 for g in groups), "rebuilt replica not preferred"

    snap = rs.snapshot()
    r0q0 = [g.replicas[0].stats.n_queries for g in groups]
    res_rec, rts_rec, hops3, sim_rec = _timed_batch(kvs, groups, snap, queries)
    assert sum(hops3) == 0, f"failed over after recovery: {hops3}"
    served = sum(g.replicas[0].stats.n_queries - q for g, q in zip(groups, r0q0))
    assert served >= 1, "rebuilt replicas served no reads"
    # version contents are immutable, so every non-evolution query matches
    # the healthy run byte-for-byte (evolutions legitimately grew by the
    # degraded-mode commits)
    for r0, r1 in zip(res_healthy, res_rec):
        if r0.query.kind != "evolution":
            assert r0.value == r1.value, f"post-recovery diverged: {r0.query}"

    recovery_bytes = sum(r.bytes_copied for r in reports)
    out = {
        "n_versions": n_versions, "n_shards": N_SHARDS,
        "replication_factor": R,
        "mixed64_read_round_trips": {"healthy": rts_healthy,
                                     "degraded": rts_degraded},
        "failover_hops": {"first_degraded_batch": hops1,
                          "second_degraded_batch": hops2},
        "mixed64_simulated_s": {"healthy": sim_healthy,
                                "degraded": sim_degraded,
                                "recovered": sim_rec,
                                "overhead_frac":
                                    sim_degraded / sim_healthy - 1.0},
        "recovery": {"round_trips": [r.round_trips for r in reports],
                     "keys_copied": sum(r.keys_copied for r in reports),
                     "bytes_copied": recovery_bytes,
                     "stale_keys_deleted":
                         sum(r.stale_keys_deleted for r in reports),
                     "wall_s": recovery_wall},
    }
    emit("fault/degraded_read", 0.0,
         f"sim_ms {sim_healthy*1e3:.2f}->{sim_degraded*1e3:.2f} "
         f"(+{(sim_degraded/sim_healthy-1)*100:.1f}%) "
         f"hops={sum(hops1)}<=1/shard-batch then {sum(hops2)}")
    emit("fault/round_trips", 0.0,
         f"healthy={rts_healthy} degraded={rts_degraded} (router-level equal)")
    emit("fault/recovery", recovery_wall * 1e6,
         f"{N_SHARDS} replicas rebuilt, {recovery_bytes} B copied, "
         f"<=4 round trips each")
    save_json("bench_fault_tolerance", out)
    return out


if __name__ == "__main__":
    run()
