"""Fig. 11: end-to-end query latency — Q1 (full version), Q2 (range),
Q3 (record evolution) — across algorithms and sub-chunk sizes, against a
random query workload, with the DELTA and SUBCHUNK baselines.

Claims: BOTTOM-UP best for Q1/Q2; Q2 tracks Q1 (partial span ∝ full span);
DELTA's Q2 ≥ its Q1 (it reconstructs then filters); larger sub-chunks help
Q3; SUBCHUNK is best for Q3 and worst for Q1.

Each workload wave runs through the plan/execute session API — the whole
batch of N_QUERIES is planned together and fetched in one KVS round trip
(see bench_batched_query.py for the round-trip comparison itself).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DatasetSpec, Q, RStore, RStoreConfig, generate

from .common import emit, save_json

SPEC = DatasetSpec(n_versions=100, n_base_records=500, pct_update=0.1,
                   record_size=512, payloads=True, p_d=0.05,
                   branch_prob=0.1, seed=13)
CAPACITY = 32 * 1024
N_QUERIES = 12


def _rstore_for(algo: str, k: int) -> RStore:
    g = generate(SPEC)
    rs = RStore(RStoreConfig(algorithm=algo, capacity=CAPACITY, k=k,
                             batch_size=10**9))
    rs.graph = g
    rs._grow_r2c()
    rs.build()
    return rs


def _workload(rs, rng):
    vids = rng.choice(rs.graph.versions, N_QUERIES)
    keys = rng.choice(rs.graph.store.keys(), N_QUERIES)
    return vids, keys


def run():
    out = {}
    rng = np.random.default_rng(5)
    for algo in ("bottom_up", "depth_first", "shingle"):
        for k in (1, 5, 25):
            rs = _rstore_for(algo, k)
            vids, keys = _workload(rs, rng)
            snap = rs.snapshot()          # session API: plan+execute batches
            t0 = time.perf_counter()
            res1 = snap.execute([Q.version(int(v)) for v in vids])
            q1 = (time.perf_counter() - t0) / N_QUERIES
            spans = [r.stats.chunks_fetched for r in res1]
            t0 = time.perf_counter()
            snap.execute([Q.range(int(v), 100, 200) for v in vids])
            q2 = (time.perf_counter() - t0) / N_QUERIES
            t0 = time.perf_counter()
            res3 = snap.execute([Q.evolution(int(kk)) for kk in keys])
            q3 = (time.perf_counter() - t0) / N_QUERIES
            kspans = [r.stats.chunks_fetched for r in res3]
            out[f"{algo}_k{k}"] = {
                "q1_s": q1, "q2_s": q2, "q3_s": q3,
                "q1_round_trips": res1.batch.kvs_queries,
                "avg_version_span": float(np.mean(spans)),
                "avg_key_span": float(np.mean(kspans)),
            }
            emit(f"fig11/{algo}/k{k}", q1 * 1e6,
                 f"q2_us={q2*1e6:.0f} q3_us={q3*1e6:.0f} "
                 f"vspan={np.mean(spans):.1f} kspan={np.mean(kspans):.1f}")

    # DELTA baseline: reconstruct along the path, then filter
    g = generate(SPEC)
    from repro.core.partition import DeltaBaseline
    db = DeltaBaseline()
    part = db.partition(g, CAPACITY)
    spans = db.version_spans(g, part)
    vids, keys = np.array(g.versions), g.store.keys()
    sel = rng.choice(vids, N_QUERIES)
    avg_delta_span = float(np.mean([spans[int(v)] for v in sel]))
    out["delta"] = {"avg_version_span": avg_delta_span,
                    "q2_note": "Q2 >= Q1 (reconstruct then filter)",
                    "q3_note": "impractical (reconstruct all versions)"}
    emit("fig11/delta", 0.0, f"vspan={avg_delta_span:.1f} (Q3 impractical)")

    # SUBCHUNK baseline: perfect Q3, catastrophic Q1
    from repro.core.partition import SubChunkPartitioner, key_spans, version_spans
    part = SubChunkPartitioner().partition(g, CAPACITY)
    vs = version_spans(g, part)
    ks = key_spans(g, part)
    out["subchunk"] = {
        "avg_version_span": float(np.mean([vs[int(v)] for v in sel])),
        "avg_key_span": float(np.mean(list(ks.values()))),
    }
    emit("fig11/subchunk", 0.0,
         f"vspan={out['subchunk']['avg_version_span']:.1f} "
         f"kspan={out['subchunk']['avg_key_span']:.1f}")
    save_json("bench_fig11_query", out)
    return out


if __name__ == "__main__":
    run()
