"""Fig. 13: online partitioning quality vs batch size.

Quality metric (the paper's): total-version-span(online @ batch B) /
total-version-span(offline BOTTOM-UP on the same versions).  Claims: ratio
≥ 1, shrinking toward 1 as the batch grows; even small batches stay within a
reasonable penalty.
"""
from __future__ import annotations

import numpy as np

from repro.core import DatasetSpec, RStore, RStoreConfig, generate
from repro.core.partition import BottomUpPartitioner, total_version_span

from .common import emit, save_json

CAPACITY = 16 * 1024


def _replay_into(rs: RStore, g) -> None:
    """Re-ingest a generated graph through the RStore commit API."""
    keys = g.store.keys()
    store = g.store
    for v in g.versions:
        d = g.tree_delta[v]
        adds = {int(keys[r]): store.payload(int(r)) for r in d.adds}
        dels = []
        if v != g.root:
            # deletions = keys removed (not superseded by adds)
            del_keys = {int(keys[r]) for r in d.dels}
            dels = sorted(del_keys - set(adds))
            if v == g.root:
                dels = []
        if v == g.root:
            rs.init_root(adds)
        else:
            parent = g.tree_parent(v)
            rs.commit([parent], adds=adds, dels=dels)


def run():
    spec = DatasetSpec(n_versions=200, n_base_records=400, pct_update=0.1,
                       record_size=256, payloads=True, branch_prob=0.0,
                       seed=17)
    out = {}
    g_ref = generate(spec)
    offline = BottomUpPartitioner().partition(g_ref, CAPACITY)
    off_span = total_version_span(g_ref, offline)

    for batch in (10, 25, 50, 100, 200):
        rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=CAPACITY,
                                 batch_size=batch))
        _replay_into(rs, generate(spec))
        rs.flush()
        spans = sum(int(np.unique(rs.r2c[rs.graph.members(v)]).size)
                    for v in rs.graph.versions)
        ratio = spans / off_span
        out[batch] = {"online_span": spans, "offline_span": off_span,
                      "ratio": ratio}
        emit(f"fig13/batch{batch}", 0.0, f"ratio={ratio:.3f}")
    save_json("bench_fig13_online", out)
    return out


if __name__ == "__main__":
    run()
