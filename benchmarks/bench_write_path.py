"""Write-path group commits: one multiput per shard vs per-blob puts.

The §2.3 argument applied to ingest: the seed's flush issued one ``kvs.put``
per chunk and per chunk map (~2×n_chunks write round trips per flush, plus
one per rebuilt old map).  A :class:`WriteSession` stages a whole wave of
commits and group-flushes them through ONE ``multiput`` — the ShardedKVS
router splits it into exactly one write round trip per shard, so a
64-version flush costs O(shards) backend writes however many chunks it
produced.  Latency is compared under the same Cassandra-like cost model the
read benchmarks use (per-request overhead dominates — the §2.3 effect,
write-side).

Asserts the acceptance criterion (64 versions, 4 shards → exactly 4 write
round trips; reads still one round trip per shard touched), so running this
under CI is a round-trip regression gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (InMemoryKVS, KVSStats, Q, RStore, RStoreConfig,
                        ShardedKVS)

from .common import emit, save_json

N_SHARDS = 4
PER_QUERY_S = 5e-4
BANDWIDTH = 200e6


def _ingest(rs, rng, n_versions, n_keys, rec_size):
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    with rs.writer() as w:
        v = w.init_root({k: pay() for k in range(n_keys)})
        for i in range(n_versions - 1):
            v = w.commit([v], adds={int(rng.integers(0, n_keys)): pay(),
                                    n_keys + i: pay()})
    return v


def run(smoke: bool = False):
    n_versions = 16 if smoke else 64
    n_keys = 40 if smoke else 200
    rec_size = 128 if smoke else 512
    # smoke sizes must still produce enough chunks to touch every shard
    capacity = 1024 if smoke else 16 * 1024

    # ---- write session over the sharded router ---------------------------
    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=10**9), kvs=kvs)
    rng = np.random.default_rng(21)
    t0 = time.perf_counter()
    last = _ingest(rs, rng, n_versions, n_keys, rec_size)
    wall = time.perf_counter() - t0

    write_rts = kvs.stats.n_put_queries
    n_blobs = kvs.stats.n_values_put
    assert write_rts == N_SHARDS, \
        f"group flush must be one multiput per shard, got {write_rts}"
    per_shard = [s.stats.n_put_queries for s in kvs.shards]
    assert per_shard == [1] * N_SHARDS, per_shard

    # seed cost: one put per blob (chunks + maps + rebuilt maps), same bytes
    seed = KVSStats(n_put_queries=n_blobs, bytes_stored=kvs.stats.bytes_stored)
    sim_grouped = kvs.stats.simulated_write_seconds(PER_QUERY_S, BANDWIDTH)
    sim_seed = seed.simulated_write_seconds(PER_QUERY_S, BANDWIDTH)

    # ---- reads through the same router: one round trip per shard touched -
    snap = rs.snapshot()
    q0 = kvs.stats.n_queries
    res = snap.execute([Q.version(last)])
    read_rts = kvs.stats.n_queries - q0
    assert 1 <= read_rts <= N_SHARDS, read_rts

    out = {
        "n_versions": n_versions,
        "n_shards": N_SHARDS,
        "grouped": {"write_round_trips": write_rts,
                    "blobs": n_blobs,
                    "bytes": kvs.stats.bytes_stored,
                    "wall_s": wall,
                    "simulated_s": sim_grouped},
        "seed_per_blob": {"write_round_trips": seed.n_put_queries,
                          "simulated_s": sim_seed},
        "read_round_trips_full_version": read_rts,
        "speedup_simulated": sim_seed / sim_grouped,
    }
    emit("write_path/grouped", wall * 1e6 / n_versions,
         f"round_trips={write_rts} blobs={n_blobs} "
         f"sim_ms={sim_grouped*1e3:.2f}")
    emit("write_path/seed_per_blob", 0.0,
         f"round_trips={seed.n_put_queries} sim_ms={sim_seed*1e3:.2f}")
    emit("write_path/speedup", 0.0,
         f"simulated {out['speedup_simulated']:.1f}x fewer backend write "
         f"seconds ({n_blobs} blobs -> {write_rts} round trips)")
    emit("write_path/read_after_write", 0.0,
         f"Q1 round_trips={read_rts} (per shard touched), "
         f"records={len(res[0].value)}")
    save_json("bench_write_path", out)
    return out


if __name__ == "__main__":
    run()
