"""Mini HLO cost analyzer over partitioned, scheduled HLO text.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE — under
scan-over-layers every per-layer FLOP/byte/collective is undercounted by the
trip count (verified empirically: a 48-layer scanned model reports ~1/13 of
its true FLOPs).  This analyzer parses the partitioned module text, builds
the computation call graph, and multiplies while bodies by their trip counts
(scan bounds are compile-time constants in the loop condition).

Counted per device (partitioned HLO shapes are shard shapes):
  flops        — dot (2·result·contraction, lhs shape via symbol table)
                 + convolution; counted inside fusions too
  bytes        — Σ over *kernel-level* ops (ENTRY + while bodies, not fusion
                 internals) of result + operand bytes: a fused-kernel HBM
                 traffic model — fusion internals live in registers/VMEM, so
                 counting at fusion boundaries approximates HBM traffic
  collectives  — (kind, bytes, group, mult) with loop multiplicity
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:[a-z][a-z0-9]*\[[^\]]*\]\S*))\s+"
    r"([a-z][a-z0-9\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "while"}

# Ops XLA performs in place / by slice: charge moved bytes, not whole buffers.
#   dynamic-slice: read+write of the slice (= result)
#   dynamic-update-slice: read+write of the update (= operand 1); the
#     enclosing buffer is aliased, not copied
#   gather/scatter: result/update bytes (+index reads, negligible)
_SLICED_OPS = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}


def _sliced_bytes(ins: "Instr", symtab: Dict[str, str]) -> float:
    ops = _OPERAND_RE.findall(ins.operands_str)
    if ins.opcode == "dynamic-slice" or ins.opcode == "gather":
        return 2.0 * _bytes_of_shape_str(ins.result_str)
    if ins.opcode == "dynamic-update-slice":
        upd = symtab.get(ops[1], "") if len(ops) > 1 else ""
        return 2.0 * _bytes_of_shape_str(upd)
    if ins.opcode == "scatter":
        upd = symtab.get(ops[-1], "") if ops else ""
        return 2.0 * _bytes_of_shape_str(upd)
    return 0.0


def _kernel_op_bytes(ins: "Instr", comp: "Computation",
                     comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of one kernel-level op under the slice-aware model."""
    if ins.opcode in _SKIP_BYTES:
        return 0.0
    if ins.opcode in _SLICED_OPS:
        return _sliced_bytes(ins, comp.symtab)
    ops = _OPERAND_RE.findall(ins.operands_str)
    if ins.opcode == "fusion":
        am = re.search(r"calls=%?([\w\.\-]+)", ins.attrs_str)
        callee = comps.get(am.group(1)) if am else None
        if callee is not None:
            # operands consumed only through dynamic-slice inside the fusion
            # are streamed by slice; a dus-rooted fusion aliases its buffer.
            param_of = {}
            for ci in callee.instrs:
                if ci.opcode == "parameter":
                    pm = re.search(r"parameter\((\d+)\)", ci.line)
                    if pm:
                        param_of[ci.name] = int(pm.group(1))
            consumers: Dict[int, List["Instr"]] = {}
            for ci in callee.instrs:
                for o in _OPERAND_RE.findall(ci.operands_str):
                    if o in param_of:
                        consumers.setdefault(param_of[o], []).append(ci)
            total = 0.0
            root = callee.instrs[-1] if callee.instrs else None
            if root is not None and root.opcode == "dynamic-update-slice":
                pass  # output aliases the input buffer; writes counted below
            else:
                total += _bytes_of_shape_str(ins.result_str)
            for i, opname in enumerate(ops):
                full = _bytes_of_shape_str(comp.symtab.get(opname, ""))
                cons = consumers.get(i, [])
                if cons and all(c.opcode in ("dynamic-slice",
                                             "dynamic-update-slice")
                                for c in cons):
                    sl = 0.0
                    for c in cons:
                        cops = _OPERAND_RE.findall(c.operands_str)
                        if c.opcode == "dynamic-slice" and cops and \
                                cops[0] in param_of and \
                                param_of[cops[0]] == i:
                            sl += 2.0 * _bytes_of_shape_str(c.result_str)
                        elif c.opcode == "dynamic-update-slice" and cops and \
                                cops[0] in param_of and param_of[cops[0]] == i:
                            upd = callee.symtab.get(cops[1], "") \
                                if len(cops) > 1 else ""
                            sl += 2.0 * _bytes_of_shape_str(upd)
                        else:
                            sl += full
                    total += min(sl, full)
                else:
                    total += full
            return total
    b = _bytes_of_shape_str(ins.result_str)
    for opnd in ops:
        b += _bytes_of_shape_str(comp.symtab.get(opnd, ""))
    return b

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _bytes_of_shape_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt in _DTYPE_BYTES:
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_str: str
    operands_str: str
    attrs_str: str
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)   # value -> shape str
    text: str = ""


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr:
            cur = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.text += line + "\n"
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_str, opcode, rest = m.groups()
        # operand section: up to the first un-nested ')'
        depth = 0
        cut = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    cut = i
                    break
                depth -= 1
        ins = Instr(name=name, opcode=opcode, result_str=result_str,
                    operands_str=rest[:cut], attrs_str=rest[cut:], line=line)
        cur.instrs.append(ins)
        cur.symtab[name] = result_str
    return comps, entry


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond.text)]
    return max(consts) if consts else 1


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    result = _elems(ins.result_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs_str)
    ops = _OPERAND_RE.findall(ins.operands_str)
    if not m or not ops or ops[0] not in symtab:
        return 2.0 * result
    lhs_dims = _dims(symtab[ops[0]])
    contract = 1
    for ix in (int(x) for x in m.group(1).split(",") if x):
        if ix < len(lhs_dims):
            contract *= lhs_dims[ix]
    return 2.0 * result * contract


def _elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    return [int(x) for x in m.group(2).split(",")] if m and m.group(2) else []


def _conv_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    result = _elems(ins.result_str)
    ops = _OPERAND_RE.findall(ins.operands_str)
    if len(ops) < 2 or ops[1] not in symtab:
        return 2.0 * result
    kdims = _dims(symtab[ops[1]])
    if not kdims:
        return 2.0 * result
    out_feat = kdims[-1]
    return 2.0 * result * math.prod(kdims) / max(out_feat, 1)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: List[Dict] = field(default_factory=list)


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[Tuple[str, bool], Costs] = {}

    def analyze(self) -> Costs:
        return self._analyze(self.entry, kernel_level=True)

    def _analyze(self, name: str, kernel_level: bool) -> Costs:
        key = (name, kernel_level)
        if key in self._memo:
            return self._memo[key]
        out = Costs()
        self._memo[key] = out
        comp = self.comps.get(name)
        if comp is None:
            return out
        for ins in comp.instrs:
            # ---------------- flops
            if ins.opcode == "dot":
                out.flops += _dot_flops(ins, comp.symtab)
            elif ins.opcode == "convolution":
                out.flops += _conv_flops(ins, comp.symtab)
            # ---------------- bytes (kernel level only)
            if kernel_level:
                out.bytes += _kernel_op_bytes(ins, comp, self.comps)
            # ---------------- collectives
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                n = 1
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.attrs_str)
                if gm:
                    n = int(gm.group(2))
                else:
                    gm = re.search(r"replica_groups=\{\{([0-9, ]*)\}",
                                   ins.attrs_str)
                    if gm:
                        n = max(1, len([x for x in gm.group(1)
                                        .replace(" ", "").split(",") if x]))
                out.collectives.append({
                    "kind": base,
                    "bytes": _bytes_of_shape_str(ins.result_str),
                    "group": n, "mult": 1})
            # ---------------- callees
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_str)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_str)
                trips = 1
                if cm and cm.group(1) in self.comps:
                    trips = _trip_count(self.comps[cm.group(1)])
                if bm:
                    sub = self._analyze(bm.group(1), kernel_level=True)
                    out.flops += trips * sub.flops
                    out.bytes += trips * sub.bytes
                    for c in sub.collectives:
                        out.collectives.append(
                            {**c, "mult": trips * c.get("mult", 1)})
            else:
                for attr in ("calls", "branch_computations"):
                    am = re.search(attr + r"=\{?%?([\w\.\-]+)", ins.attrs_str)
                    if am and am.group(1) in self.comps:
                        sub = self._analyze(am.group(1), kernel_level=False)
                        out.flops += sub.flops
                        out.bytes += sub.bytes
                        out.collectives.extend(sub.collectives)
        return out


def xla_cost_analysis(compiled) -> Dict:
    """XLA's own per-module cost dict, normalized across jax versions
    (newer jax returns one dict; older returns a list of per-computation
    dicts — take the entry module's)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca


def analyze_text(hlo_text: str) -> Costs:
    return HloAnalyzer(hlo_text).analyze()


def collective_cost_bytes(colls: List[Dict]) -> float:
    """Per-device ring-model bytes across all collectives."""
    total = 0.0
    for c in colls:
        n, b = c["group"], c["bytes"] * c.get("mult", 1)
        if n <= 1:
            continue
        k = c["kind"]
        if k == "all-reduce":
            total += 2.0 * (n - 1) / n * b
        elif k == "all-gather":
            total += (n - 1) / n * b
        elif k == "reduce-scatter":
            total += float(n - 1) * b
        elif k in ("all-to-all", "ragged-all-to-all"):
            total += (n - 1) / n * b
        elif k == "collective-permute":
            total += float(b)
    return total


# --------------------------------------------------------------- attribution
def flops_breakdown(hlo_text: str, top: int = 25) -> List[Tuple[str, float]]:
    """Attribute dot/conv FLOPs to jax op_name metadata (loop-multiplied).

    Returns the top-N (op_name, flops) pairs — the dry-run profiler used by
    the §Perf iterations."""
    an = HloAnalyzer(hlo_text)
    agg: Dict[str, float] = {}

    def walk(name: str, mult: float, seen):
        comp = an.comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen | {name}
        for ins in comp.instrs:
            fl = 0.0
            if ins.opcode == "dot":
                fl = _dot_flops(ins, comp.symtab)
            elif ins.opcode == "convolution":
                fl = _conv_flops(ins, comp.symtab)
            if fl:
                m = re.search(r'op_name="([^"]+)"', ins.line)
                label = m.group(1) if m else f"<{name}>"
                agg[label] = agg.get(label, 0.0) + fl * mult
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_str)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_str)
                trips = _trip_count(an.comps[cm.group(1)]) \
                    if cm and cm.group(1) in an.comps else 1
                if bm:
                    walk(bm.group(1), mult * trips, seen)
            else:
                am = re.search(r"(?:calls|branch_computations)=\{?%?([\w\.\-]+)",
                               ins.attrs_str)
                if am and am.group(1) in an.comps:
                    walk(am.group(1), mult, seen)

    walk(an.entry, 1.0, frozenset())
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


def bytes_breakdown(hlo_text: str, top: int = 25) -> List[Tuple[str, float]]:
    """Attribute kernel-level HBM-traffic bytes to op_name metadata."""
    an = HloAnalyzer(hlo_text)
    agg: Dict[str, float] = {}

    def walk(name: str, mult: float, seen):
        comp = an.comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen | {name}
        for ins in comp.instrs:
            if ins.opcode not in _SKIP_BYTES:
                b = _kernel_op_bytes(ins, comp, an.comps)
                if b:
                    m = re.search(r'op_name="([^"]+)"', ins.line)
                    label = m.group(1) if m else f"<{ins.opcode}>"
                    agg[label] = agg.get(label, 0.0) + b * mult
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_str)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_str)
                trips = _trip_count(an.comps[cm.group(1)]) \
                    if cm and cm.group(1) in an.comps else 1
                if bm:
                    walk(bm.group(1), mult * trips, seen)

    walk(an.entry, 1.0, frozenset())
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


def collective_breakdown(hlo_text: str, top: int = 25) -> List[Tuple[str, float]]:
    """Attribute ring-model collective bytes to op_name metadata."""
    an = HloAnalyzer(hlo_text)
    agg: Dict[str, float] = {}

    def walk(name: str, mult: float, seen):
        comp = an.comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen | {name}
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                n = 1
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.attrs_str)
                if gm:
                    n = int(gm.group(2))
                cost = collective_cost_bytes([{
                    "kind": base, "bytes": _bytes_of_shape_str(ins.result_str),
                    "group": n, "mult": 1}])
                if cost:
                    m = re.search(r'op_name="([^"]+)"', ins.line)
                    label = (m.group(1) if m else f"<{base}>") + f" [{base} n={n}]"
                    agg[label] = agg.get(label, 0.0) + cost * mult
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs_str)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs_str)
                trips = _trip_count(an.comps[cm.group(1)]) \
                    if cm and cm.group(1) in an.comps else 1
                if bm:
                    walk(bm.group(1), mult * trips, seen)
            else:
                am = re.search(r"calls=\{?%?([\w\.\-]+)", ins.attrs_str)
                if am and am.group(1) in an.comps:
                    walk(am.group(1), mult, seen)

    walk(an.entry, 1.0, frozenset())
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]
