"""Fig. 9: effect of the β subtree bound on BOTTOM-UP (dataset B0 analogue).

Claims: span grows as β shrinks; runtime first drops with smaller β (less
processing per node) then rises again for very small β (merge overhead).
"""
from __future__ import annotations

import time

from repro.core import PAPER_DATASETS, generate
from repro.core.partition import BottomUpPartitioner, total_version_span

from .common import emit, save_json

CAPACITY = 64 * 1024


def run():
    g = generate(PAPER_DATASETS["B0"])
    out = {}
    for beta in (2, 5, 10, 20, 50, 100, 1000):
        t0 = time.perf_counter()
        part = BottomUpPartitioner(beta=beta).partition(g, CAPACITY)
        dt = time.perf_counter() - t0
        span = total_version_span(g, part)
        out[beta] = {"span": span, "seconds": dt}
        emit(f"fig9/beta_{beta}", dt * 1e6, f"span={span}")
    save_json("bench_fig9_beta", out)
    return out


if __name__ == "__main__":
    run()
