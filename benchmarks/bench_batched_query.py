"""Batched multi-query sessions vs sequential retrieval (§2.3 revisited).

The paper's core argument is that few large batched fetches beat many small
ones.  The plan/execute engine extends that from records-within-a-query to
queries-within-a-session: a server-side wave of 64 mixed queries (Q1 full
versions, point lookups, Q2 ranges, Q3 evolutions) is planned in one
vectorized projection pass, its candidate chunks deduped across queries, and
chunks + chunk maps fetched in ONE interleaved multiget.

Measured here against the same workload driven through the per-query
wrappers (1 round trip each) and the seed's two-phase cost (2 round trips
each: chunks, then maps), with latency under the Cassandra-like cost model
(per-request overhead dominates at this scale — exactly the §2.3 effect).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DatasetSpec, Q, RStore, RStoreConfig, generate
from repro.core.kvs import KVSStats

from .common import emit, save_json

SPEC = DatasetSpec(n_versions=120, n_base_records=600, pct_update=0.1,
                   record_size=512, payloads=True, p_d=0.05,
                   branch_prob=0.1, seed=17)
SMOKE_SPEC = DatasetSpec(n_versions=30, n_base_records=150, pct_update=0.1,
                         record_size=128, payloads=True, p_d=0.05,
                         branch_prob=0.1, seed=17)
CAPACITY = 32 * 1024
BATCH = 64


def _mixed_workload(rs, rng, n=BATCH):
    vids = rs.graph.versions
    keys = rs.graph.store.keys()
    qs = []
    for i in range(n):
        v = int(rng.choice(vids))
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.choice(keys))))
        elif kind == 2:
            lo = int(rng.integers(0, 500))
            qs.append(Q.range(v, lo, lo + 80))
        else:
            qs.append(Q.evolution(int(rng.choice(keys))))
    return qs


def _cost(stats: KVSStats) -> float:
    return stats.simulated_seconds()


def run(smoke: bool = False):
    rng = np.random.default_rng(7)
    g = generate(SMOKE_SPEC if smoke else SPEC)
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=CAPACITY,
                             batch_size=10**9))
    rs.graph = g
    rs._grow_r2c()
    rs.build()
    qs = _mixed_workload(rs, rng, n=16 if smoke else BATCH)
    snap = rs.snapshot()

    # ---- batched session: one planned wave, one round trip ---------------
    before = rs.kvs.stats.snapshot()
    t0 = time.perf_counter()
    res = snap.execute(qs)
    wall_batched = time.perf_counter() - t0
    d_batched = rs.kvs.stats.snapshot()
    d_batched.n_queries -= before.n_queries
    d_batched.bytes_fetched -= before.bytes_fetched
    assert d_batched.n_queries == 1, \
        f"batched session must be 1 round trip, got {d_batched.n_queries}"

    # ---- sequential wrappers: one single-query session each --------------
    before = rs.kvs.stats.snapshot()
    t0 = time.perf_counter()
    seq_vals = [snap.execute([q])[0].value for q in qs]
    wall_seq = time.perf_counter() - t0
    d_seq = rs.kvs.stats.snapshot()
    d_seq.n_queries -= before.n_queries
    d_seq.bytes_fetched -= before.bytes_fetched

    for r, sv in zip(res, seq_vals):
        assert r.value == sv, "batched result diverged from sequential"

    # seed cost: two multigets per query (chunks, then maps), same bytes
    seed_stats = KVSStats(n_queries=2 * len(qs),
                          bytes_fetched=d_seq.bytes_fetched)

    out = {
        "n_queries": len(qs),
        "batched": {"round_trips": d_batched.n_queries,
                    "bytes": d_batched.bytes_fetched,
                    "chunks": res.batch.chunks_fetched,
                    "wall_s": wall_batched,
                    "simulated_s": _cost(d_batched)},
        "sequential": {"round_trips": d_seq.n_queries,
                       "bytes": d_seq.bytes_fetched,
                       "wall_s": wall_seq,
                       "simulated_s": _cost(d_seq)},
        "seed_two_phase": {"round_trips": seed_stats.n_queries,
                           "simulated_s": _cost(seed_stats)},
    }
    out["speedup_simulated"] = out["sequential"]["simulated_s"] / \
        out["batched"]["simulated_s"]
    emit("batched_query/batched", wall_batched * 1e6 / len(qs),
         f"round_trips=1 bytes={d_batched.bytes_fetched} "
         f"sim_ms={_cost(d_batched)*1e3:.2f}")
    emit("batched_query/sequential", wall_seq * 1e6 / len(qs),
         f"round_trips={d_seq.n_queries} sim_ms={_cost(d_seq)*1e3:.2f}")
    emit("batched_query/seed_two_phase", 0.0,
         f"round_trips={seed_stats.n_queries} "
         f"sim_ms={_cost(seed_stats)*1e3:.2f}")
    emit("batched_query/speedup", 0.0,
         f"simulated {out['speedup_simulated']:.1f}x fewer backend seconds")
    save_json("bench_batched_query", out)
    return out


if __name__ == "__main__":
    run()
