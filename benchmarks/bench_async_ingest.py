"""Async ingest: cross-session background drains vs per-session sync flush.

The write-path bench showed one session's group commit costs O(shards)
round trips; this one shows the :class:`~repro.core.flusher.BackgroundFlusher`
extends that across sessions — K concurrent sessions staging at ZERO round
trips per commit and draining together in ≤S write round trips on S shards,
where per-session synchronous flushes pay ~K·S.  Latency compared under the
same Cassandra-like cost model (per-request overhead dominates — §2.3,
write-side).

Asserts the acceptance criteria (8 sessions × 64 versions on 4 shards: one
cross-session drain ≤ 4 write round trips, per-commit stage cost = 0 round
trips, ≥3x lower simulated write seconds than per-session sync flush), plus
the degraded-mode contract: the same workload on replicated shards with one
replica of every group killed mid-drain stays byte-identical to the
synchronous-flush oracle, and recover_all converges every replica.  Running
this under CI is the async-ingest regression gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (FaultInjectingKVS, InMemoryKVS, RecoveryManager,
                        ReplicatedKVS, RStore, RStoreConfig, ShardedKVS)

from .common import emit, save_json

N_SHARDS = 4
N_SESSIONS = 8
PER_QUERY_S = 5e-4
BANDWIDTH = 200e6


def _cfg(capacity):
    return RStoreConfig(algorithm="bottom_up", capacity=capacity,
                        batch_size=10**9)


def _drive_async(rs, rng, n_versions, n_keys, rec_size):
    """Stage the canonical workload through N_SESSIONS concurrent sessions
    (round-robin interleaved), then barrier once.  Returns (heads, drain
    report, staging round trips observed)."""
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    with rs.writer() as boot:
        root = boot.init_root({k: pay() for k in range(n_keys)})
    sessions = [rs.writer() for _ in range(N_SESSIONS)]
    heads = [root] * N_SESSIONS
    stage_rts = rs.kvs.stats.n_put_queries + rs.kvs.stats.n_queries
    for i in range(n_versions - 1):
        for j, w in enumerate(sessions):
            heads[j] = w.commit(
                [heads[j]], adds={int(rng.integers(0, n_keys)): pay(),
                                  n_keys + i * N_SESSIONS + j: pay()})
    stage_rts = (rs.kvs.stats.n_put_queries + rs.kvs.stats.n_queries
                 - stage_rts)
    rep = rs.barrier()
    for w in sessions:
        w.close()
    return heads, rep, stage_rts


def _drive_sync(rs, rng, n_versions, n_keys, rec_size):
    """Same total commit volume, but each session is its own synchronous
    group flush (the pre-flusher way to run K writers).  Cost baseline
    only — per-session vid order differs from the interleaved runs."""
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    with rs.writer() as boot:
        root = boot.init_root({k: pay() for k in range(n_keys)})
    heads = [root] * N_SESSIONS
    for j in range(N_SESSIONS):
        with rs.writer() as w:
            for i in range(n_versions - 1):
                heads[j] = w.commit(
                    [heads[j]], adds={int(rng.integers(0, n_keys)): pay(),
                                      n_keys + i * N_SESSIONS + j: pay()})
    return heads


def _drive_oracle(rs, rng, n_versions, n_keys, rec_size):
    """Synchronous-flush oracle: the SAME round-robin commit sequence as
    :func:`_drive_async`, but every commit is its own flush
    (``batch_size=1``).  Same sequence -> same vids -> byte-identical
    contents, however the async runs buffer or fail over."""
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    root = rs.init_root({k: pay() for k in range(n_keys)})
    heads = [root] * N_SESSIONS
    for i in range(n_versions - 1):
        for j in range(N_SESSIONS):
            heads[j] = rs.commit(
                [heads[j]], adds={int(rng.integers(0, n_keys)): pay(),
                                  n_keys + i * N_SESSIONS + j: pay()})
    return heads


def run(smoke: bool = False):
    n_versions = 8 if smoke else 64       # per session
    n_keys = 40 if smoke else 200
    rec_size = 128 if smoke else 256
    capacity = 1024 if smoke else 8 * 1024

    # ---- async: K sessions, one cross-session drain ----------------------
    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs = RStore(_cfg(capacity), kvs=kvs)
    rs.attach_flusher(max_staged_versions=10**9, max_staged_bytes=1 << 62)
    t0 = time.perf_counter()
    heads, rep, stage_rts = _drive_async(
        rs, np.random.default_rng(33), n_versions, n_keys, rec_size)
    wall_async = time.perf_counter() - t0
    assert stage_rts == 0, \
        f"per-commit stage cost must be 0 round trips, saw {stage_rts}"
    assert rep.write_round_trips <= N_SHARDS, \
        (f"cross-session drain must cost <= {N_SHARDS} write round trips, "
         f"got {rep.write_round_trips}")
    sim_async = kvs.stats.simulated_write_seconds(PER_QUERY_S, BANDWIDTH)
    async_rts = kvs.stats.n_put_queries

    # ---- baseline: per-session synchronous group flushes -----------------
    kvs0 = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs0 = RStore(_cfg(capacity), kvs=kvs0)
    t0 = time.perf_counter()
    heads0 = _drive_sync(rs0, np.random.default_rng(33), n_versions, n_keys,
                         rec_size)
    wall_sync = time.perf_counter() - t0
    sim_sync = kvs0.stats.simulated_write_seconds(PER_QUERY_S, BANDWIDTH)
    sync_rts = kvs0.stats.n_put_queries
    speedup = sim_sync / sim_async
    assert speedup >= 3.0, \
        f"async drain must be >=3x cheaper in simulated write seconds, got {speedup:.2f}x"

    # ---- synchronous-flush oracle (same round-robin sequence) ------------
    rs_or = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                                batch_size=1), kvs=InMemoryKVS())
    heads_or = _drive_oracle(rs_or, np.random.default_rng(33), n_versions,
                             n_keys, rec_size)
    assert heads == heads_or
    for v in heads_or:
        assert rs.get_version(v)[0] == rs_or.get_version(v)[0], \
            "async run diverged from synchronous-flush oracle"

    # ---- degraded mode: replicated shards, one replica killed mid-drain --
    groups = [ReplicatedKVS(
        [FaultInjectingKVS(InMemoryKVS(), seed=70 + i * 2 + r)
         for r in range(2)], write_quorum=1) for i in range(N_SHARDS)]
    kvs2 = ShardedKVS(groups)
    rs2 = RStore(_cfg(capacity), kvs=kvs2)
    rs2.attach_flusher(max_staged_versions=10**9)
    rng2 = np.random.default_rng(33)

    def pay2():
        return rng2.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    with rs2.writer() as boot:
        root2 = boot.init_root({k: pay2() for k in range(n_keys)})
    sessions2 = [rs2.writer() for _ in range(N_SESSIONS)]
    heads2 = [root2] * N_SESSIONS
    killed = False
    for i in range(n_versions - 1):
        if not killed and i >= (n_versions - 1) // 2:
            # first buffer is durable; kill replica 0 of every group so the
            # NEXT drain discovers the dead replica and fails over mid-batch
            rs2.barrier()
            for g in groups:
                g.replicas[0].kill()
            killed = True
        for j, w in enumerate(sessions2):
            heads2[j] = w.commit(
                [heads2[j]], adds={int(rng2.integers(0, n_keys)): pay2(),
                                   n_keys + i * N_SESSIONS + j: pay2()})
    rs2.barrier()                          # drains through the failover
    for w in sessions2:
        w.close()
    assert heads2 == heads_or
    for v in heads_or:
        assert rs2.get_version(v)[0] == rs_or.get_version(v)[0], \
            "degraded async run diverged from synchronous-flush oracle"
    # recovery: every replica of every group converges byte-identically
    for g in groups:
        g.replicas[0].revive()
    RecoveryManager(kvs2).recover_all()
    for g in groups:
        want = dict(g.replicas[0].inner.scan())
        for idx, r in enumerate(g.replicas):
            assert dict(r.inner.scan()) == want
            assert g.pending_repairs(idx) == 0

    total_versions = 1 + N_SESSIONS * (n_versions - 1)
    out = {
        "n_sessions": N_SESSIONS,
        "n_versions_per_session": n_versions,
        "n_shards": N_SHARDS,
        "total_versions": total_versions,
        "async": {"stage_round_trips": stage_rts,
                  "drain_round_trips": rep.write_round_trips,
                  "total_write_round_trips": async_rts,
                  "wall_s": wall_async,
                  "simulated_s": sim_async},
        "sync_per_session": {"total_write_round_trips": sync_rts,
                             "wall_s": wall_sync,
                             "simulated_s": sim_sync},
        "speedup_simulated": speedup,
        "degraded_byte_identical": True,
    }
    emit("async_ingest/stage", 0.0,
         f"{total_versions} versions staged at {stage_rts} round trips")
    emit("async_ingest/drain", wall_async * 1e6 / total_versions,
         f"{N_SESSIONS} sessions -> {rep.write_round_trips} write round "
         f"trips (<= {N_SHARDS} shards), sim_ms={sim_async*1e3:.2f}")
    emit("async_ingest/sync_baseline", wall_sync * 1e6 / total_versions,
         f"round_trips={sync_rts} sim_ms={sim_sync*1e3:.2f}")
    emit("async_ingest/speedup", 0.0,
         f"simulated {speedup:.1f}x fewer backend write seconds "
         f"({sync_rts} -> {async_rts} round trips)")
    emit("async_ingest/degraded", 0.0,
         "replica killed mid-drain: byte-identical to sync oracle, "
         "recover_all converged")
    save_json("bench_async_ingest", out)
    return out


if __name__ == "__main__":
    run()
