"""Secondary indexes: filtered scans without full-version fetches.

The workload the subsystem exists for: "all records of version v where
field X = y" on a store whose payloads carry a structured attribute prefix
(the ``DatasetSpec.attr_fields`` layout, read by
``repro.core.secondary.datagen_extractor``).  Without a secondary index the
only plan is fetch-the-whole-version-and-filter; with one, the plan is
secondary-bitmap ∧ version-bitmap through the session kernel launch plus an
exact post-filter on the (few) fetched chunks.

Asserts the acceptance criteria, which are also the CI smoke gates:

1. SELECTIVITY — across a sweep of predicates, the filtered scan fetches
   ≤ 25% of the chunks the full-version baseline fetches for the same
   predicate, and its §2.3 simulated seconds are ≥ 4x lower;
2. EXACTNESS — every filtered result is byte-identical to the brute-force
   filter of the full fetch (lossy postings never leak);
3. WARM CACHE — with a ``CachingKVS`` on top, a repeated filtered scan runs
   with 0 backend read round trips.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CachingKVS, InMemoryKVS, KVSStats, Q, RStore,
                        RStoreConfig, ShardedKVS)
from repro.core.costmodel import BANDWIDTH_BPS, PER_QUERY_S
from repro.core.secondary import datagen_extractor

from .common import emit, save_json

N_SHARDS = 2
ATTR = "f0"                       # first uint32 of the datagen attr layout


def _make_store(capacity: int, cache_bytes: int = 0):
    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    if cache_bytes:
        kvs = CachingKVS(kvs, cache_bytes=cache_bytes)
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=8), kvs=kvs)
    rs.create_index(ATTR, datagen_extractor(1))
    return rs


def _ingest(rs, rng, n_keys, n_versions, rec_size, cardinality):
    def pay():
        tag = int(rng.integers(0, cardinality))
        return tag.to_bytes(4, "little") + rng.integers(
            0, 256, rec_size - 4, dtype=np.uint8).tobytes()

    with rs.writer() as w:
        v = w.init_root({pk: pay() for pk in range(n_keys)})
        vids = [v]
        for _ in range(n_versions - 1):
            ks = rng.choice(n_keys, size=max(2, n_keys // 64), replace=False)
            v = w.commit([v], adds={int(k): pay() for k in ks})
            vids.append(v)
    return vids


def _sim(batch) -> float:
    return KVSStats(n_queries=batch.kvs_queries,
                    bytes_fetched=batch.bytes_fetched).simulated_seconds(
                        PER_QUERY_S, BANDWIDTH_BPS)


def run(smoke: bool = False):
    n_keys = 3000 if smoke else 8000
    n_versions = 6 if smoke else 16
    rec_size = 512
    capacity = 32 << 10
    cardinality = 1024 if smoke else 2048
    n_predicates = 8

    rs = _make_store(capacity)
    vids = _ingest(rs, np.random.default_rng(7), n_keys, n_versions,
                   rec_size, cardinality)
    snap = rs.snapshot()
    ext = datagen_extractor(1)

    # predicates: attribute values that actually occur in the newest version
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0]
    seen = list({ext(p)[ATTR] for p in full.value.values()})
    tags = seen[:n_predicates]

    # ---- gates 1+2: per-predicate filtered session vs full-fetch session --
    flt_chunks = full_chunks = 0
    flt_sim = full_sim = 0.0
    for tag in tags:
        base = snap.execute([Q.version(v)])           # fetch-all baseline
        want = {pk: p for pk, p in base[0].value.items()
                if ext(p)[ATTR] == tag}
        got = snap.execute([Q.where(v, ATTR, tag)])   # indexed plan
        assert got[0].value == want, f"filtered scan diverged for tag {tag}"
        flt_chunks += got[0].stats.chunks_fetched
        full_chunks += base[0].stats.chunks_fetched
        flt_sim += _sim(got.batch)
        full_sim += _sim(base.batch)

    chunk_frac = flt_chunks / max(1, full_chunks)
    speedup = full_sim / max(flt_sim, 1e-12)
    assert chunk_frac <= 0.25, f"filtered scan fetched {chunk_frac:.0%} of chunks"
    assert speedup >= 4.0, f"simulated speedup only {speedup:.2f}x"

    # where_range exactness on the same store (a band of attribute values)
    lo, hi = min(tags), min(tags) + cardinality // 8
    want = {pk: p for pk, p in full.value.items()
            if lo <= ext(p)[ATTR] <= hi}
    got = snap.execute([Q.where_range(v, ATTR, lo, hi)])[0]
    assert got.value == want, "where_range diverged from brute-force filter"

    # ---- gate 3: warm cached filtered scans = 0 read round trips ----------
    rs_c = _make_store(capacity, cache_bytes=64 << 20)
    vids_c = _ingest(rs_c, np.random.default_rng(7), n_keys, n_versions,
                     rec_size, cardinality)
    assert vids_c == vids
    snap_c = rs_c.snapshot()
    queries = [Q.where(v, ATTR, tag) for tag in tags]
    cold = snap_c.execute(queries)
    assert cold.batch.kvs_queries >= 1
    warm = snap_c.execute(queries)
    assert warm.batch.kvs_queries == 0, warm.batch.kvs_queries
    for a, b in zip(warm, cold):
        assert a.value == b.value, "warm cached filtered scan diverged"

    st = rs.storage_stats()
    out = {
        "n_keys": n_keys, "n_versions": n_versions, "n_shards": N_SHARDS,
        "cardinality": cardinality, "n_predicates": len(tags),
        "chunks": {"filtered": flt_chunks, "full": full_chunks,
                   "fraction": chunk_frac},
        "simulated_s": {"filtered": flt_sim, "full": full_sim,
                        "speedup": speedup},
        "warm_cached_round_trips": warm.batch.kvs_queries,
        "secondary_index_bytes": st["secondary_index_bytes"],
        "index_report": st["secondary_indexes"][ATTR],
        "stored_chunk_bytes": st["stored_chunk_bytes"],
    }
    emit("secondary/filtered_scan", 0.0,
         f"chunks {flt_chunks}/{full_chunks} ({chunk_frac:.1%}<=25%) "
         f"sim {full_sim*1e3:.2f}->{flt_sim*1e3:.2f}ms ({speedup:.1f}x>=4x)")
    emit("secondary/warm_cached", 0.0,
         f"{len(tags)} filtered scans warm rts=0")
    emit("secondary/index_cost", 0.0,
         f"{st['secondary_index_bytes']}B postings vs "
         f"{st['stored_chunk_bytes']}B chunks")
    save_json("bench_secondary", out)
    return out


if __name__ == "__main__":
    run()
