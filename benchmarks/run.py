"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes JSON payloads under benchmarks/results/.  An aggregate
``BENCH_SUMMARY.json`` — per-bench headline metrics keyed by suite name,
plus wall time and pass/fail status — lands at the repo root so a single
file answers "what did the last bench run say".  The dry-run/roofline sweep
(launch/dryrun.py) is separate — it needs the 512-device platform flag.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

SUMMARY_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_SUMMARY.json"


def _jsonable(obj):
    """Best-effort conversion of bench payloads (numpy scalars etc.)."""
    if obj is None:
        return None
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    for t in (bool, int, float, str):
        if isinstance(obj, t):
            return t(obj)
    if hasattr(obj, "item"):          # numpy scalar
        return obj.item()
    return repr(obj)


def main() -> None:
    from . import (bench_async_ingest, bench_batched_query, bench_cache,
                   bench_chunksize, bench_compaction, bench_fault_tolerance,
                   bench_fig8_span, bench_fig9_beta, bench_fig10_compression,
                   bench_fig11_query, bench_fig12_scaling, bench_fig13_online,
                   bench_secondary, bench_table1, bench_write_path)

    suites = [
        ("table1_costmodel", bench_table1.run),
        ("sec2.3_chunksize", bench_chunksize.run),
        ("fig8_span", bench_fig8_span.run),
        ("fig9_beta", bench_fig9_beta.run),
        ("fig10_compression", bench_fig10_compression.run),
        ("fig11_query", bench_fig11_query.run),
        ("batched_query", bench_batched_query.run),
        ("write_path", bench_write_path.run),
        ("async_ingest", bench_async_ingest.run),
        ("compaction", bench_compaction.run),
        ("fault_tolerance", bench_fault_tolerance.run),
        ("chunk_cache", bench_cache.run),
        ("secondary_index", bench_secondary.run),
        ("fig12_scaling", bench_fig12_scaling.run),
        ("fig13_online", bench_fig13_online.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    summary = {}
    for name, fn in suites:
        t0 = time.time()
        try:
            headline = fn()
            wall = time.time() - t0
            print(f"suite/{name},{wall*1e6:.0f},ok")
            summary[name] = {"status": "ok", "wall_s": round(wall, 3),
                             "headline": _jsonable(headline)}
        except Exception as e:  # noqa: BLE001
            failures += 1
            wall = time.time() - t0
            print(f"suite/{name},0,FAILED:{type(e).__name__}:{e}")
            summary[name] = {"status": f"FAILED:{type(e).__name__}:{e}",
                             "wall_s": round(wall, 3), "headline": None}
    SUMMARY_PATH.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"# wrote {SUMMARY_PATH}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
