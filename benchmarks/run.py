"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes JSON payloads under benchmarks/results/.  The dry-run/roofline sweep
(launch/dryrun.py) is separate — it needs the 512-device platform flag.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_batched_query, bench_cache, bench_chunksize,
                   bench_compaction, bench_fault_tolerance, bench_fig8_span,
                   bench_fig9_beta, bench_fig10_compression,
                   bench_fig11_query, bench_fig12_scaling, bench_fig13_online,
                   bench_table1, bench_write_path)

    suites = [
        ("table1_costmodel", bench_table1.run),
        ("sec2.3_chunksize", bench_chunksize.run),
        ("fig8_span", bench_fig8_span.run),
        ("fig9_beta", bench_fig9_beta.run),
        ("fig10_compression", bench_fig10_compression.run),
        ("fig11_query", bench_fig11_query.run),
        ("batched_query", bench_batched_query.run),
        ("write_path", bench_write_path.run),
        ("compaction", bench_compaction.run),
        ("fault_tolerance", bench_fault_tolerance.run),
        ("chunk_cache", bench_cache.run),
        ("fig12_scaling", bench_fig12_scaling.run),
        ("fig13_online", bench_fig13_online.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"suite/{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"suite/{name},0,FAILED:{type(e).__name__}:{e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
