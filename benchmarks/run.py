"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes JSON payloads under benchmarks/results/.  An aggregate
``BENCH_SUMMARY.json`` — per-bench headline metrics keyed by suite name,
plus wall time and pass/fail status, stamped with the git SHA, a UTC
timestamp and a schema version so runs across PRs are directly diffable —
lands at the repo root so a single file answers "what did the last bench
run say".  The dry-run/roofline sweep (launch/dryrun.py) is separate — it
needs the 512-device platform flag.
"""
from __future__ import annotations

import datetime
import json
import pathlib
import subprocess
import sys
import time

SUMMARY_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_SUMMARY.json"

# bump when the summary layout changes (suites moved under "suites",
# metadata stamp added)
SCHEMA_VERSION = 2


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=SUMMARY_PATH.parent, capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — not a repo / no git: still stamp
        return "unknown"


def _jsonable(obj):
    """Best-effort conversion of bench payloads (numpy scalars etc.)."""
    if obj is None:
        return None
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    for t in (bool, int, float, str):
        if isinstance(obj, t):
            return t(obj)
    if hasattr(obj, "item"):          # numpy scalar
        return obj.item()
    return repr(obj)


def main() -> None:
    from . import (bench_async_ingest, bench_batched_query, bench_cache,
                   bench_chunksize, bench_compaction, bench_fault_tolerance,
                   bench_fig8_span, bench_fig9_beta, bench_fig10_compression,
                   bench_fig11_query, bench_fig12_scaling, bench_fig13_online,
                   bench_planner, bench_secondary, bench_table1,
                   bench_write_path)

    suites = [
        ("table1_costmodel", bench_table1.run),
        ("sec2.3_chunksize", bench_chunksize.run),
        ("fig8_span", bench_fig8_span.run),
        ("fig9_beta", bench_fig9_beta.run),
        ("fig10_compression", bench_fig10_compression.run),
        ("fig11_query", bench_fig11_query.run),
        ("batched_query", bench_batched_query.run),
        ("write_path", bench_write_path.run),
        ("async_ingest", bench_async_ingest.run),
        ("compaction", bench_compaction.run),
        ("fault_tolerance", bench_fault_tolerance.run),
        ("chunk_cache", bench_cache.run),
        ("secondary_index", bench_secondary.run),
        ("query_planner", bench_planner.run),
        ("fig12_scaling", bench_fig12_scaling.run),
        ("fig13_online", bench_fig13_online.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    suite_results = {}
    for name, fn in suites:
        t0 = time.time()
        try:
            headline = fn()
            wall = time.time() - t0
            print(f"suite/{name},{wall*1e6:.0f},ok")
            suite_results[name] = {"status": "ok", "wall_s": round(wall, 3),
                                   "headline": _jsonable(headline)}
        except Exception as e:  # noqa: BLE001
            failures += 1
            wall = time.time() - t0
            print(f"suite/{name},0,FAILED:{type(e).__name__}:{e}")
            suite_results[name] = {"status": f"FAILED:{type(e).__name__}:{e}",
                                   "wall_s": round(wall, 3), "headline": None}
    summary = {
        "schema_version": SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "generated_at_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "n_suites": len(suites),
        "n_failures": failures,
        "suites": suite_results,
    }
    SUMMARY_PATH.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"# wrote {SUMMARY_PATH}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
