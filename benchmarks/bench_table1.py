"""Table 1: analytical cost model vs the instrumented system on a chain.

For a chain workload (n versions, m_v records, update fraction d) we compare
the closed-form storage / #queries / bytes predictions with measurements from
the built system for RStore-chunking, SINGLE-ADDRESS, SUBCHUNK and DELTA.
"""
from __future__ import annotations

import numpy as np

from repro.core import DatasetSpec, costmodel, generate
from repro.core.partition import (ALGORITHMS, DeltaBaseline,
                                  SingleAddressPartitioner,
                                  SubChunkPartitioner, total_version_span,
                                  version_spans)

from .common import emit, save_json

N, M, D, S = 60, 400, 0.10, 256
CAP = 8 * 1024


def run():
    spec = DatasetSpec(n_versions=N, n_base_records=M, pct_update=D,
                       frac_modify=1.0, frac_insert=0.0, frac_delete=0.0,
                       record_size=S, branch_prob=0.0, seed=23)
    g = generate(spec)
    w = costmodel.Workload(n=N, m_v=M, d=D, c=0.3, s=S, s_c=CAP)
    out = {}

    # --- storage: measured unique bytes vs single-address prediction -------
    measured_storage = int(g.store.sizes.sum())
    predicted = costmodel.single_address(w)["storage"]
    out["storage"] = {"measured": measured_storage, "predicted": predicted,
                      "rel_err": abs(measured_storage - predicted) / predicted}
    emit("table1/storage", 0.0,
         f"measured={measured_storage} predicted={predicted:.0f} "
         f"err={out['storage']['rel_err']:.2%}")

    # --- version query count: RStore chunking vs m_v·s/s_c -----------------
    part = ALGORITHMS["bottom_up"]().partition(g, CAP)
    spans = version_spans(g, part)
    avg_span = float(np.mean(list(spans.values())))
    pred_q = costmodel.rstore(w)["version_queries"]
    out["rstore_version_queries"] = {"measured": avg_span, "predicted_floor": pred_q}
    emit("table1/rstore_vq", 0.0,
         f"measured_span={avg_span:.1f} floor={pred_q:.1f} "
         f"span_factor={avg_span/pred_q:.2f}")

    # --- single-address: one query per record ------------------------------
    sa = SingleAddressPartitioner().partition(g, CAP)
    sa_span = float(np.mean(list(version_spans(g, sa).values())))
    out["single_address_vq"] = {"measured": sa_span,
                                "predicted": costmodel.single_address(w)["version_queries"]}
    emit("table1/single_address_vq", 0.0,
         f"measured={sa_span:.0f} predicted={M}")

    # --- delta: half-chain retrieval for a random version ------------------
    db = DeltaBaseline()
    dpart = db.partition(g, CAP)
    dspans = db.version_spans(g, dpart)
    avg_chain_chunks = float(np.mean(list(dspans.values())))
    pred_bytes = costmodel.delta(w)["version_bytes"]
    measured_bytes = avg_chain_chunks * CAP
    out["delta_version_bytes"] = {"measured": measured_bytes,
                                  "predicted": pred_bytes}
    emit("table1/delta_bytes", 0.0,
         f"measured≈{measured_bytes:.2e} predicted={pred_bytes:.2e} "
         f"(c≈{measured_bytes/ (w.m_v*w.s + w.d*(w.n-1)*w.m_v*w.s/2) :.2f})")

    # --- subchunk: key span = 1 ---------------------------------------------
    from repro.core.partition import key_spans
    sc = SubChunkPartitioner().partition(g, CAP)
    ks = key_spans(g, sc)
    out["subchunk_point"] = {"measured_key_span": float(np.mean(list(ks.values()))),
                             "predicted": 1.0}
    emit("table1/subchunk_kspan", 0.0,
         f"measured={out['subchunk_point']['measured_key_span']:.2f} predicted=1")

    save_json("bench_table1", out)
    return out


if __name__ == "__main__":
    run()
