"""Roofline analysis from compiled dry-run artifacts (TPU v5e model).

Three terms per (arch × shape × mesh), all in seconds-per-step:

  compute    = per-device HLO FLOPs / peak (197 TFLOP/s bf16)
  memory     = per-device HBM-traffic bytes / HBM bw (819 GB/s)
  collective = Σ per-op ring-model bytes / ICI bw (~50 GB/s per chip)

FLOPs/bytes/collectives come from ``benchmarks.hlo_analysis`` — a call-graph
walker over the partitioned HLO that multiplies while-loop (scan) bodies by
their trip counts.  XLA's own ``cost_analysis()`` visits loop bodies once and
undercounts scanned models by the layer count (verified; see hlo_analysis
docstring + tests).  Both numbers are recorded: ``xla_cost_analysis`` for
reference, the corrected numbers for the roofline.

Ring-model collective costs over replica-group size N:
  all-reduce        2·(N-1)/N · result_bytes
  all-gather          (N-1)/N · result_bytes
  reduce-scatter      (N-1)   · result_bytes      (input = N · result)
  all-to-all          (N-1)/N · result_bytes
  collective-permute            result_bytes
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Optional

from . import hlo_analysis

# ----------------------------------------------------------- hardware model
PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per chip (~1 link)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    coll_counts: Dict[str, int]
    model_flops_global: float          # 6·N·D (train) / 2·N·tokens (serve)
    n_chips: int
    xla_flops: float = 0.0             # raw cost_analysis, for reference
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.n_chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s achieved over the cluster peak, if the step
        runs at max(term) seconds (an MFU bound derived from the dry-run)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops_global / (self.n_chips * PEAK_FLOPS * t)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "coll_counts": dict(self.coll_counts),
            "model_flops_global": self.model_flops_global,
            "n_chips": self.n_chips,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS: 6·N_active·tokens for training, 2·N_active·tokens for
    inference (decode counts the new tokens only)."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n_active * seq * batch
    if shape_kind == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch          # decode: one token per sequence


def analyze(compiled, cfg, shape_kind: str, seq: int, batch: int,
            n_chips: int) -> Roofline:
    hlo = compiled.as_text()
    costs = hlo_analysis.analyze_text(hlo)
    ca = hlo_analysis.xla_cost_analysis(compiled)
    counts = Counter()
    for c in costs.collectives:
        counts[c["kind"]] += c.get("mult", 1)
    return Roofline(
        flops_per_device=float(costs.flops),
        bytes_per_device=float(costs.bytes),
        collective_bytes=hlo_analysis.collective_cost_bytes(costs.collectives),
        coll_counts=dict(counts),
        model_flops_global=model_flops(cfg, shape_kind, seq, batch),
        n_chips=n_chips,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )
