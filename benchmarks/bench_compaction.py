"""Compaction & retention GC: storage reclaimed and query seconds won back.

The degradation workload the maintenance path exists for: a long online
chain (a ``VersionedCheckpointer`` committing training steps — §4 appends
every batch as fresh chunks and never revisits old ones), then
``keep_last(k)`` retention and ONE compaction pass.  Measures, before vs
after: total stored bytes, the layout-health fragmentation score, and the
simulated read seconds (the §2.3 Cassandra-like model) of a 64-query mixed
batch over the retained window.

Asserts the acceptance criteria — ≥30% of stored bytes reclaimed, the mixed
batch measurably faster, retained versions byte-identical — and the
round-trip contract (one multiput round trip per shard the rewrite touches
plus one multidelete round trip per shard the GC touches), so running this
under CI is a maintenance-path regression gate.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (InMemoryKVS, KVSStats, Q, RStore, RStoreConfig,
                        ShardedKVS, keep_last, measure_layout)

from .common import emit, save_json

N_SHARDS = 4
PER_QUERY_S = 5e-4
BANDWIDTH = 200e6


def _ingest_chain(rs, rng, n_versions, n_keys, rec_size):
    """Checkpointer-like churn: fixed keyspace, every commit overwrites a
    couple of blocks — the workload whose old copies all eventually die."""
    def pay():
        return rng.integers(0, 256, rec_size, dtype=np.uint8).tobytes()

    v = rs.init_root({k: pay() for k in range(n_keys)})
    vids = [v]
    for _ in range(n_versions - 1):
        ks = rng.choice(n_keys, size=2, replace=False)
        v = rs.commit([v], adds={int(k): pay() for k in ks})
        vids.append(v)
    rs.flush()
    return vids


def _mixed_queries(vids, n_keys, rng, n=64):
    qs = []
    for i in range(n):
        v = vids[i % len(vids)]
        kind = i % 4
        if kind == 0:
            qs.append(Q.version(v))
        elif kind == 1:
            qs.append(Q.record(v, int(rng.integers(0, n_keys))))
        elif kind == 2:
            lo = int(rng.integers(0, n_keys))
            qs.append(Q.range(v, lo, lo + n_keys // 8))
        else:
            qs.append(Q.evolution(int(rng.integers(0, n_keys))))
    return qs


def _simulated_read(kvs, snap, queries):
    s0 = kvs.stats.snapshot()
    res = snap.execute(queries)
    d = KVSStats(n_queries=kvs.stats.n_queries - s0.n_queries,
                 bytes_fetched=kvs.stats.bytes_fetched - s0.bytes_fetched)
    return d.simulated_seconds(PER_QUERY_S, BANDWIDTH), res


def run(smoke: bool = False):
    n_versions = 32 if smoke else 512
    keep = 8 if smoke else 64
    n_keys = 24 if smoke else 96
    rec_size = 128 if smoke else 512
    capacity = 1024 if smoke else 8192
    batch = 8 if smoke else 32

    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=batch), kvs=kvs)
    rng = np.random.default_rng(33)
    vids = _ingest_chain(rs, rng, n_versions, n_keys, rec_size)
    kept = vids[-keep:]

    queries = _mixed_queries(kept, n_keys, np.random.default_rng(34))
    stored_before = kvs.total_stored_bytes()

    # ---- retention, then measure the degraded layout ---------------------
    # (retention is the *logical* change — evolution queries legitimately
    # stop seeing dropped versions' copies — but it moves no bytes, so reads
    # here still price the degraded pre-compaction layout)
    rs.retain(keep_last(keep))
    h_before = measure_layout(rs)
    sim_before, res_before = _simulated_read(kvs, rs.snapshot(), queries)

    # ---- ONE compaction pass ---------------------------------------------
    puts0 = [s.stats.n_put_queries for s in kvs.shards]
    dels0 = [s.stats.n_delete_queries for s in kvs.shards]
    t0 = time.perf_counter()
    rep = rs.compact()
    wall = time.perf_counter() - t0
    assert rep.mode == "pass", rep.mode

    # round-trip contract: ONE multiput per shard the writes touch, ONE
    # multidelete per shard the deletes touch
    dput = [s.stats.n_put_queries - p for s, p in zip(kvs.shards, puts0)]
    ddel = [s.stats.n_delete_queries - d for s, d in zip(kvs.shards, dels0)]
    assert all(d <= 1 for d in dput), f"multiput split per shard: {dput}"
    assert all(d <= 1 for d in ddel), f"multidelete split per shard: {ddel}"
    assert rep.write_round_trips == sum(dput) >= 1, (rep.write_round_trips, dput)
    assert rep.delete_round_trips == sum(ddel) >= 1, (rep.delete_round_trips, ddel)

    stored_after = kvs.total_stored_bytes()
    h_after = measure_layout(rs)
    reclaimed = 1.0 - stored_after / stored_before
    sim_after, res_after = _simulated_read(kvs, rs.snapshot(), queries)

    # retained versions byte-identical through the rewritten layout
    for r0, r1 in zip(res_before, res_after):
        assert r0.value == r1.value, f"result diverged for {r0.query}"
    assert reclaimed >= 0.30, f"only {reclaimed:.1%} of stored bytes reclaimed"
    assert sim_after < sim_before, "compaction did not reduce read seconds"

    out = {
        "n_versions": n_versions, "keep_last": keep, "n_shards": N_SHARDS,
        "stored_bytes": {"before": stored_before, "after": stored_after,
                         "reclaimed_frac": reclaimed},
        "frag_score": {"before": h_before.frag_score,
                       "after": h_after.frag_score},
        "dead_frac_before_pass": h_before.dead_frac,
        "mixed64_simulated_s": {"before": sim_before, "after": sim_after,
                                "speedup": sim_before / sim_after},
        "pass": {"chunks_deleted": rep.chunks_deleted,
                 "chunks_written": rep.chunks_written,
                 "records_dropped": rep.records_dropped,
                 "write_round_trips": rep.write_round_trips,
                 "delete_round_trips": rep.delete_round_trips,
                 "wall_s": wall},
    }
    emit("compaction/storage", 0.0,
         f"reclaimed={reclaimed:.1%} ({stored_before}->{stored_after} B)")
    emit("compaction/frag_score", 0.0,
         f"{h_before.frag_score:.2f}->{h_after.frag_score:.2f}")
    emit("compaction/mixed64_read", 0.0,
         f"sim_ms {sim_before*1e3:.2f}->{sim_after*1e3:.2f} "
         f"({sim_before/sim_after:.2f}x)")
    emit("compaction/round_trips", wall * 1e6,
         f"multiput={rep.write_round_trips}/shard<=1 "
         f"multidelete={rep.delete_round_trips}/shard<=1 "
         f"({rep.chunks_deleted} chunks -> {rep.chunks_written})")
    save_json("bench_compaction", out)
    return out


if __name__ == "__main__":
    run()
