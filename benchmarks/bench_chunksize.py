"""§2.3 "too many queries" microbenchmark.

The paper's table: reconstructing a ~100K-record version from Cassandra takes
65.42 s with per-record gets and 0.56 s with 10000-record chunks.  We
reproduce the *shape* of that curve (monotone ≫1× improvement with chunk
size) against (a) the instrumented InMemoryKVS with the Cassandra-like
latency model and (b) the real ShardedDeviceKVS gather path.
"""
from __future__ import annotations

import numpy as np

from repro.core import DatasetSpec, generate
from repro.core.kvs import InMemoryKVS, ShardedDeviceKVS

from .common import emit, save_json, timed


def run():
    n_records = 20_000
    record_size = 100
    rng = np.random.default_rng(0)
    payloads = [rng.integers(0, 256, record_size, dtype=np.uint8).tobytes()
                for _ in range(n_records)]

    out = {}
    for chunk_records in (1, 10, 100, 1000, 10000):
        kvs = InMemoryKVS()
        dev = ShardedDeviceKVS(slot_bytes=max(4096, chunk_records * record_size))
        n_chunks = n_records // chunk_records
        for c in range(n_chunks):
            blob = b"".join(payloads[c * chunk_records:(c + 1) * chunk_records])
            kvs.put(f"c{c}", blob)
            dev.put(f"c{c}", blob)
        keys = [f"c{c}" for c in range(n_chunks)]

        kvs.stats.reset()
        if chunk_records == 1:
            kvs.multiget_naive(keys)       # the naive per-record pattern
        else:
            kvs.multiget(keys)
        sim_s = kvs.stats.n_values * 5e-4 + kvs.stats.bytes_fetched / 200e6

        _, real_s = timed(dev.multiget, keys)
        out[chunk_records] = {"simulated_s": sim_s, "device_gather_s": real_s,
                              "kvs_values": kvs.stats.n_values}
        emit(f"chunksize/{chunk_records}", real_s * 1e6,
             f"simulated_cassandra_s={sim_s:.3f}")

    speedup = out[1]["simulated_s"] / out[10000]["simulated_s"]
    emit("chunksize/speedup_1_to_10000", 0.0,
         f"{speedup:.0f}x (paper: 65.42/0.56 = 117x)")
    save_json("bench_chunksize", out)
    return out


if __name__ == "__main__":
    run()
