"""Unified query planner: composite pushdown + index-only execution gates.

The planner's two headline promises, asserted as CI smoke gates:

1. COMPOSITE PUSHDOWN — ``Q.and_(Q.where(...), Q.where_range(...))`` runs
   as ONE ``and_popcount``-family kernel launch and ONE interleaved
   multiget, fetches FEWER chunks than either predicate alone, and is
   byte-identical to the client-side two-session intersection it replaces
   (which paid two launches and two multigets).
2. INDEX-ONLY AGGREGATES — ``Q.count`` / ``Q.distinct`` on an indexed
   attribute answer from postings + chunk maps with ZERO chunk-payload
   read round trips (``stats.payload_round_trips == 0``).

Also reports predicted (``snap.explain``) vs measured chunk fetches — the
costmodel's plan-time view against the lossy-projection reality.
"""
from __future__ import annotations

import numpy as np

from repro.core import (InMemoryKVS, KVSStats, Q, RStore, RStoreConfig,
                        ShardedKVS)
from repro.core.costmodel import BANDWIDTH_BPS, PER_QUERY_S
from repro.core.secondary import datagen_extractor
from repro.kernels import ops

from .common import emit, save_json

N_SHARDS = 2
A0, A1 = "f0", "f1"               # two uint32 attrs of the datagen layout


def _make_store(capacity: int):
    kvs = ShardedKVS([InMemoryKVS() for _ in range(N_SHARDS)])
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=capacity,
                             batch_size=8), kvs=kvs)
    rs.create_index(A0, datagen_extractor(2))
    rs.create_index(A1, datagen_extractor(2))
    return rs


def _ingest(rs, rng, n_keys, n_versions, rec_size, card0, card1):
    def pay():
        t0 = int(rng.integers(0, card0))
        t1 = int(rng.integers(0, card1))
        return (t0.to_bytes(4, "little") + t1.to_bytes(4, "little")
                + rng.integers(0, 256, rec_size - 8, dtype=np.uint8).tobytes())

    with rs.writer() as w:
        v = w.init_root({pk: pay() for pk in range(n_keys)})
        vids = [v]
        for _ in range(n_versions - 1):
            ks = rng.choice(n_keys, size=max(2, n_keys // 64), replace=False)
            v = w.commit([v], adds={int(k): pay() for k in ks})
            vids.append(v)
    return vids


def _sim(batch) -> float:
    return KVSStats(n_queries=batch.kvs_queries,
                    bytes_fetched=batch.bytes_fetched).simulated_seconds(
                        PER_QUERY_S, BANDWIDTH_BPS)


def run(smoke: bool = False):
    n_keys = 3000 if smoke else 8000
    n_versions = 4 if smoke else 12
    rec_size = 256
    capacity = 16 << 10
    card0, card1 = 128, 4096

    rs = _make_store(capacity)
    vids = _ingest(rs, np.random.default_rng(11), n_keys, n_versions,
                   rec_size, card0, card1)
    snap = rs.snapshot()
    ext = datagen_extractor(2)
    v = vids[-1]
    full = snap.execute([Q.version(v)])[0].value

    # two predicates that are each selective at chunk granularity
    some = ext(next(iter(full.values())))
    t0, (lo, hi) = some[A0], (some[A1], some[A1] + 15)
    composite = Q.and_(Q.where(v, A0, t0), Q.where_range(v, A1, lo, hi))

    # ---- gate 1: ONE launch + ONE multiget, fewer chunks, byte-identical --
    launches0 = ops.BITMAP_LAUNCHES
    got = snap.execute([composite])
    launches = ops.BITMAP_LAUNCHES - launches0
    assert launches == 1, f"composite AND took {launches} kernel launches"
    # sharded stats count per-shard round trips: ONE multiget <= N_SHARDS
    assert got.batch.kvs_queries <= N_SHARDS, got.batch.kvs_queries

    a = snap.execute([Q.where(v, A0, t0)])
    b = snap.execute([Q.where_range(v, A1, lo, hi)])
    want = {pk: p for pk, p in a[0].value.items()
            if pk in b[0].value and b[0].value[pk] == p}
    assert got[0].value == want, "composite diverged from 2-session intersect"
    oracle = {pk: p for pk, p in full.items()
              if ext(p)[A0] == t0 and lo <= ext(p)[A1] <= hi}
    assert got[0].value == oracle, "composite diverged from brute-force scan"

    and_chunks = got[0].stats.chunks_fetched
    a_chunks = a[0].stats.chunks_fetched
    b_chunks = b[0].stats.chunks_fetched
    assert and_chunks < min(a_chunks, b_chunks), (
        f"AND fetched {and_chunks} chunks, predicates alone fetched "
        f"{a_chunks}/{b_chunks}")
    and_sim = _sim(got.batch)
    two_sim = _sim(a.batch) + _sim(b.batch)

    # ---- gate 2: index-only count/distinct = 0 payload round trips --------
    agg = snap.execute([Q.count(Q.where(v, A0, t0)),
                        Q.distinct(v, A0),
                        Q.exists(Q.where_range(v, A1, lo, hi))])
    assert agg[0].value == sum(1 for p in full.values() if ext(p)[A0] == t0)
    assert agg[1].value == sorted({ext(p)[A0] for p in full.values()})
    assert agg[2].value is True
    for r in agg:
        assert r.stats.payload_round_trips == 0, r.stats
        assert r.stats.payload_chunks_fetched == 0, r.stats
    assert agg.batch.payload_round_trips == 0, agg.batch

    # predicted vs measured chunk fetches (explain's costmodel view)
    ex = snap.explain([composite])[0]
    predicted, measured = ex["predicted_chunks"], and_chunks

    out = {
        "n_keys": n_keys, "n_versions": n_versions, "n_shards": N_SHARDS,
        "composite": {
            "kernel_launches": launches,
            "round_trips": got.batch.kvs_queries,
            "chunks": {"and": and_chunks, "where": a_chunks,
                       "where_range": b_chunks},
            "records": len(got[0].value),
            "simulated_s": {"and": and_sim, "two_sessions": two_sim},
        },
        "index_only": {
            "count": agg[0].value,
            "n_distinct": len(agg[1].value),
            "payload_round_trips": agg.batch.payload_round_trips,
            "map_round_trips": agg.batch.kvs_queries,
        },
        "explain": {"predicted_chunks": predicted,
                    "measured_chunks": measured,
                    "mode": ex["mode"]},
    }
    emit("planner/composite_and", 0.0,
         f"1 launch 1 multiget chunks {and_chunks}<min({a_chunks},{b_chunks}) "
         f"sim {two_sim*1e3:.2f}->{and_sim*1e3:.2f}ms")
    emit("planner/index_only", 0.0,
         f"count+distinct+exists payload_rts=0 "
         f"(map rts={agg.batch.kvs_queries})")
    emit("planner/explain", 0.0,
         f"predicted {predicted} vs measured {measured} chunks")
    save_json("bench_planner", out)
    return out


if __name__ == "__main__":
    run()
