"""Fig. 8: total version span, BOTTOM-UP vs SHINGLE vs DFS vs BFS vs DELTA,
across the Table-2 dataset families (scaled-down, structure-identical).

Claims validated (EXPERIMENTS.md §Fig8):
  - BOTTOM-UP/SHINGLE/DFS all beat DELTA on every dataset;
  - BOTTOM-UP outperforms DELTA by multiples (paper: up to 8.21×, avg 3.56×);
  - BREADTHFIRST ≥ DEPTHFIRST everywhere, equal on linear chains.
"""
from __future__ import annotations

import time

from repro.core import PAPER_DATASETS, generate
from repro.core.partition import (ALGORITHMS, DeltaBaseline,
                                  total_version_span)

from .common import emit, save_json

ALGOS = ["bottom_up", "shingle", "depth_first", "breadth_first"]
CAPACITY = 64 * 1024          # ~1 MB in the paper; scaled with record count


def run(datasets=None):
    out = {}
    ratios = []
    for name, spec in (datasets or PAPER_DATASETS).items():
        g = generate(spec)
        row = {}
        for algo in ALGOS:
            t0 = time.perf_counter()
            part = ALGORITHMS[algo]().partition(g, CAPACITY)
            dt = time.perf_counter() - t0
            span = total_version_span(g, part)
            row[algo] = {"span": span, "chunks": part.num_chunks,
                         "seconds": dt}
        db = DeltaBaseline()
        part = db.partition(g, CAPACITY)
        row["delta"] = {"span": db.total_version_span(g, part),
                        "chunks": part.num_chunks}
        out[name] = row
        ratio = row["delta"]["span"] / row["bottom_up"]["span"]
        ratios.append(ratio)
        emit(f"fig8/{name}/bottom_up", row["bottom_up"]["seconds"] * 1e6,
             f"span={row['bottom_up']['span']} delta_span={row['delta']['span']} "
             f"ratio={ratio:.2f}x")
    emit("fig8/avg_delta_over_bottomup", 0.0,
         f"{sum(ratios)/len(ratios):.2f}x (paper avg 3.56x, max 8.21x)")
    save_json("bench_fig8_span", out)
    return out


if __name__ == "__main__":
    run()
