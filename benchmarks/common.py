"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
RESULTS.mkdir(exist_ok=True)

_rows: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV row contract: name,us_per_call,derived."""
    _rows.append({"name": name, "us_per_call": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def save_json(name: str, payload) -> None:
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=2))


def rows():
    return list(_rows)
