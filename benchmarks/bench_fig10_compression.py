"""Fig. 10: partitioning quality + compression ratio vs max sub-chunk size k,
at bounded per-record change P_d ∈ {10%, 5%, 1%}.

Claims: compression ratio grows with k and with smaller P_d; the total
version span balances Factor 1 (bigger sub-chunks → fewer relevant records
per fetched chunk → more chunks per version) against Factor 2 (compression →
fewer chunks overall); at small P_d Factor 2 wins.
"""
from __future__ import annotations

import numpy as np

from repro.core import DatasetSpec, generate
from repro.core.partition import BottomUpPartitioner
from repro.core.subchunk import (build_subchunks, build_transformed,
                                 compressed_subchunk_sizes)

from .common import emit, save_json

CAPACITY = 32 * 1024


def run():
    out = {}
    for p_d in (0.10, 0.05, 0.01):
        spec = DatasetSpec(n_versions=120, n_base_records=600, pct_update=0.2,
                           frac_modify=1.0, frac_insert=0.0, frac_delete=0.0,
                           record_size=1024, payloads=True, p_d=p_d,
                           branch_prob=0.1, seed=9)
        g = generate(spec)
        raw_total = int(g.store.sizes.sum())
        row = {}
        for k in (1, 2, 5, 10, 25, 50):
            groups = build_subchunks(g, k)
            sizes = compressed_subchunk_sizes(g, groups)
            tds = build_transformed(g, groups, sizes)
            part = BottomUpPartitioner().partition(tds.tgraph, CAPACITY)
            r2c = part.record_to_chunk[tds.rec_to_sub]
            span = int(sum(np.unique(r2c[m]).size
                           for m in g.memberships().values()))
            ratio = raw_total / float(sizes.sum())
            row[k] = {"span": span, "compression_ratio": ratio,
                      "chunks": part.num_chunks}
            emit(f"fig10/pd{int(p_d*100)}/k{k}", 0.0,
                 f"span={span} compression={ratio:.2f}x chunks={part.num_chunks}")
        out[f"pd_{p_d}"] = row
    save_json("bench_fig10_compression", out)
    return out


if __name__ == "__main__":
    run()
