"""Fig. 12: weak scalability — double the devices AND the data, measure Q1/Q3.

The paper doubles a Cassandra cluster 1→16 nodes while doubling versions; we
shard the ShardedDeviceKVS over 1→16 host devices (separate subprocess so the
device count can differ from the dry-run's 512) and scale the version count
with the device count.  Claim: query times grow mildly (span growth), i.e.
weak scaling holds.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .common import emit, save_json

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
sys.path.insert(0, "src")
import numpy as np
import jax
from repro.core import DatasetSpec, RStore, RStoreConfig, generate
from repro.core.kvs import ShardedDeviceKVS

ndev = int(sys.argv[1])
base_versions = 40
spec = DatasetSpec(n_versions=base_versions * ndev, n_base_records=400,
                   pct_update=0.1, record_size=256, payloads=True,
                   branch_prob=0.05, seed=21)
g = generate(spec)
mesh = jax.make_mesh((ndev,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
kvs = ShardedDeviceKVS(slot_bytes=32 * 1024, n_slots=256, mesh=mesh)
rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=24 * 1024,
                         batch_size=10**9), kvs=kvs)
rs.graph = g
rs._grow_r2c()
rs.build()

rng = np.random.default_rng(0)
vids = rng.choice(g.versions, 8)
keys = rng.choice(g.store.keys(), 8)
# warmup (compile the gather)
rs.get_version(int(vids[0]))
t0 = time.perf_counter(); spans = []
for v in vids:
    _, st = rs.get_version(int(v)); spans.append(st.chunks_fetched)
q1 = (time.perf_counter() - t0) / len(vids)
t0 = time.perf_counter(); kspans = []
for k in keys:
    _, st = rs.get_evolution(int(k)); kspans.append(st.chunks_fetched)
q3 = (time.perf_counter() - t0) / len(keys)
print(json.dumps({"ndev": ndev, "versions": spec.n_versions,
                  "q1_s": q1, "q3_s": q3,
                  "avg_version_span": float(np.mean(spans)),
                  "avg_key_span": float(np.mean(kspans))}))
"""


def run():
    out = {}
    for ndev in (1, 2, 4, 8, 16):
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD, str(ndev)],
            capture_output=True, text=True, timeout=900,
            cwd=pathlib.Path(__file__).resolve().parents[1])
        if proc.returncode != 0:
            emit(f"fig12/ndev{ndev}", 0.0, f"ERROR {proc.stderr[-200:]}")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        out[ndev] = rec
        emit(f"fig12/ndev{ndev}", rec["q1_s"] * 1e6,
             f"versions={rec['versions']} vspan={rec['avg_version_span']:.1f} "
             f"q3_us={rec['q3_s']*1e6:.0f} kspan={rec['avg_key_span']:.1f}")
    save_json("bench_fig12_scaling", out)
    return out


if __name__ == "__main__":
    run()
