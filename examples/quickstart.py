"""Quickstart: RStore as a versioned document store (the paper's API).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import InMemoryKVS, Q, RStore, RStoreConfig, ShardedKVS

rng = np.random.default_rng(0)


def doc(payload: str) -> bytes:
    """Records are opaque bytes — JSON documents here."""
    return ('{"record": "%s", "blob": "%s"}'
            % (payload, "x" * 64)).encode()


def main():
    kvs = ShardedKVS([InMemoryKVS() for _ in range(4)])  # 4-shard backend
    rs = RStore(RStoreConfig(algorithm="bottom_up",   # the paper's best
                             capacity=4096,           # chunk size C
                             k=3,                     # sub-chunk compression
                             batch_size=4),           # online batching (§4)
                kvs=kvs)

    # -- write session: stage a wave of commits, flush once ----------------
    # All chunks + maps of the whole session reach the backend as ONE
    # multiput per shard (the group commit).
    with rs.writer() as w:
        v0 = w.init_root({pk: doc(f"patient-{pk}/baseline")
                          for pk in range(50)})
        v1 = w.commit([v0], adds={7: doc("patient-7/updated-labs")})
        v2 = w.commit([v0], adds={50: doc("patient-50/new-enrollee")},
                      dels=[3])
        v3 = w.commit([v1, v2], adds={8: doc("patient-8/merged-analysis")})
    print(f"4-version write session = {kvs.stats.n_put_queries} write round "
          f"trips over {len(kvs.shards)} shards "
          f"({kvs.stats.n_values_put} blobs)")

    # -- session API: plan a wave of queries, execute in ONE round trip ----
    snap = rs.snapshot()                       # immutable read view
    res = snap.execute([
        Q.version(v3),                         # Q1: full version
        Q.record(v3, 7),                       # point lookup
        Q.records(v3, [8, 50]),                # multi-point
        Q.range(v3, 10, 19),                   # Q2: key range
        Q.evolution(7),                        # Q3: record history
    ])
    records = res[0].value
    print(f"version {v3}: {len(records)} records; whole 5-query session = "
          f"{res.batch.kvs_queries} KVS round trip "
          f"({res.batch.chunks_fetched} deduped chunks, "
          f"{res.batch.bytes_fetched} bytes)")
    print("patient 7 at v3:", res[1].value[:40], "...")
    print("patients {8, 50}:", sorted(res[2].value))
    print("range [10, 19]:", sorted(res[3].value))
    print("evolution of patient 7:", [(v, p[:28]) for v, p in res[4].value])

    # -- per-query wrappers (single-query sessions) still work -------------
    rec, stats = rs.get_record(v3, 7)
    print(f"wrapper get_record: {stats.kvs_queries} round trip, "
          f"{stats.chunks_fetched} chunk(s)")

    # -- storage ------------------------------------------------------------
    print("storage:", rs.storage_stats())


if __name__ == "__main__":
    main()
