"""Quickstart: RStore as a versioned document store (the paper's API).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import RStore, RStoreConfig

rng = np.random.default_rng(0)


def doc(payload: str) -> bytes:
    """Records are opaque bytes — JSON documents here."""
    return ('{"record": "%s", "blob": "%s"}'
            % (payload, "x" * 64)).encode()


def main():
    rs = RStore(RStoreConfig(algorithm="bottom_up",   # the paper's best
                             capacity=4096,           # chunk size C
                             k=3,                     # sub-chunk compression
                             batch_size=4))           # online batching (§4)

    # -- commit a root collection and a few derived versions ---------------
    v0 = rs.init_root({pk: doc(f"patient-{pk}/baseline") for pk in range(50)})
    v1 = rs.commit([v0], adds={7: doc("patient-7/updated-labs")})
    v2 = rs.commit([v0], adds={50: doc("patient-50/new-enrollee")}, dels=[3])
    v3 = rs.commit([v1, v2], adds={8: doc("patient-8/merged-analysis")})

    # -- Q1: full version retrieval ----------------------------------------
    records, stats = rs.get_version(v3)
    print(f"version {v3}: {len(records)} records via "
          f"{stats.chunks_fetched} chunks, {stats.kvs_queries} KVS queries")

    # -- Q-point / Q2: record + range retrieval ----------------------------
    rec, _ = rs.get_record(v3, 7)
    print("patient 7 at v3:", rec[:40], "...")
    rng_recs, _ = rs.get_range(v3, 10, 19)
    print("range [10, 19]:", sorted(rng_recs))

    # -- Q3: record evolution ----------------------------------------------
    evo, _ = rs.get_evolution(7)
    print("evolution of patient 7:", [(v, p[:28]) for v, p in evo])

    # -- storage ------------------------------------------------------------
    print("storage:", rs.storage_stats())


if __name__ == "__main__":
    main()
