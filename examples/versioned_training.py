"""End-to-end driver: train a ~100M-param model for a few hundred steps with
RStore-versioned checkpointing, simulate a crash, restart bit-identically,
and fork a branch (the paper's branched version graphs, realized as ML
experiment lineage).

Run:  PYTHONPATH=src python examples/versioned_training.py [--steps 200]
(~100M params on CPU: uses smollm-360m at trimmed depth; pass --full-360m to
train the whole 32-layer config if you have the patience.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import synthetic_batch
from repro.models.model import build_model
from repro.train.checkpoint import VersionedCheckpointer
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-360m", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS["smollm-360m"]
    if not args.full_360m:
        # ~100M params: keep width/vocab, trim depth 32→8
        cfg = cfg.__class__(**{**cfg.__dict__, "n_layers": 8})
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32", "remat": "none"})
    print(f"arch {cfg.name}: {cfg.param_count()/1e6:.0f}M params")

    model = build_model(cfg)
    opt = make_optimizer(cfg, lr=1e-3)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt = VersionedCheckpointer()

    v = ckpt.commit(state, parents=(), tag="init")
    t0 = time.time()
    crash_at = args.steps // 2
    for i in range(crash_at):
        state, m = step(state, synthetic_batch(cfg, i, args.batch, args.seq))
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)")
        if (i + 1) % 50 == 0:
            v = ckpt.commit(state, parents=(v,), tag=f"step{i+1}")
    v_mid = ckpt.commit(state, parents=(v,), tag=f"step{crash_at}")
    print(f"--- simulated crash at step {crash_at}; restarting from "
          f"version {v_mid} ---")

    # restart: fresh state object restored from the store
    state2 = ckpt.restore(v_mid, like=init_state(cfg, opt, jax.random.PRNGKey(0)))
    for i in range(crash_at, args.steps):
        state2, m = step(state2, synthetic_batch(cfg, i, args.batch, args.seq))
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}")
    v_main = ckpt.commit(state2, parents=(v_mid,), tag="main")

    # fork a branch from the mid checkpoint (different data order)
    branch = ckpt.restore(v_mid, like=state2)
    for i in range(crash_at, crash_at + 20):
        branch, _ = step(branch, synthetic_batch(cfg, 10_000 + i,
                                                 args.batch, args.seq))
    v_branch = ckpt.commit(branch, parents=(v_mid,), tag="fork")

    st = ckpt.storage_stats()
    print(f"versions: {ckpt.rs.graph.num_versions} "
          f"(main={v_main}, branch={v_branch})")
    print(f"stored {st['stored_chunk_bytes']/2**20:.1f} MiB in "
          f"{st['n_chunks']} chunks; raw unique "
          f"{st['raw_unique_bytes']/2**20:.1f} MiB")
    evo = ckpt.evolution("params/final_norm", 0)
    print(f"Q3 over params/final_norm block 0: {len(evo)} distinct versions")


if __name__ == "__main__":
    main()
