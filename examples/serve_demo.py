"""Batched serving demo: prefill + jitted greedy decode over a reduced arch,
with a versioned model registry (serve the model at any RStore version).

Model restores ride the plan/execute session API: a full restore is a
one-query session (Q1) and a partial restore batches one ``Q.records`` query
per tensor — either way the registry pays a single KVS round trip, which is
what lets a serving fleet hot-swap model versions without hammering the
backing store.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch granite-moe-1b-a400m]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import synthetic_batch
from repro.models.model import build_model
from repro.serve.engine import Engine
from repro.train.checkpoint import VersionedCheckpointer
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m",
                    choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    cfg = cfg.__class__(**{**cfg.__dict__, "remat": "none"})
    model = build_model(cfg)
    opt = make_optimizer(cfg)

    # "train" two quick model versions and register them
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    ckpt = VersionedCheckpointer()
    v0 = ckpt.commit(state, parents=(), tag="init")
    for i in range(5):
        state, _ = step(state, synthetic_batch(cfg, i, 4, 64))
    v1 = ckpt.commit(state, parents=(v0,), tag="tuned")

    prompts = {"tokens": synthetic_batch(cfg, 0, args.batch,
                                         args.prompt_len)["tokens"]}
    kvs_stats = ckpt.rs.kvs.stats
    for version in (v0, v1):
        q0 = kvs_stats.n_queries
        params = ckpt.restore(version, like=state)["params"]
        print(f"restore@v{version}: {kvs_stats.n_queries - q0} KVS round "
              f"trip(s) (batched session)")
        eng = Engine(cfg, params, max_len=args.prompt_len + args.gen + 8)
        t0 = time.time()
        toks = eng.generate(prompts, steps=args.gen)
        dt = time.time() - t0
        tps = args.batch * args.gen / dt
        print(f"model@v{version}: generated {toks.shape} in {dt:.2f}s "
              f"({tps:.1f} tok/s) — first row: {np.asarray(toks[0])[:8]}")

    # partial restore (elastic rescale): every embedding tensor in one
    # multi-point session — one KVS round trip regardless of tensor count
    q0 = kvs_stats.n_queries
    partial = ckpt.restore_tensors(v1, prefixes=("params",))
    print(f"partial restore of {len(partial)} tensors: "
          f"{kvs_stats.n_queries - q0} KVS round trip(s)")


if __name__ == "__main__":
    main()
