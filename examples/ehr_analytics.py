"""The paper's motivating scenario (Example 1): collaborating teams of
analysts maintain branched versions of an EHR collection; RStore answers
full-version, cohort-range, and patient-history queries.

Run:  PYTHONPATH=src python examples/ehr_analytics.py
"""
import json
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import RStore, RStoreConfig

rng = np.random.default_rng(42)
N_PATIENTS = 400


def ehr(pid: int, **fields) -> bytes:
    base = {"patient": pid, "age": int(30 + pid % 50),
            "labs": {"a1c": 5.4, "ldl": 110}}
    base.update(fields)
    return json.dumps(base).encode()


def main():
    rs = RStore(RStoreConfig(algorithm="bottom_up", capacity=16 * 1024,
                             k=4, batch_size=8))

    v_base = rs.init_root({p: ehr(p) for p in range(N_PATIENTS)})

    # Team A: diabetes model scores for the 50-60 cohort (keys 200-299 say)
    team_a = rs.commit([v_base], adds={
        p: ehr(p, diabetes_risk=float(rng.random())) for p in range(200, 300)})
    # Team A iterates
    team_a2 = rs.commit([team_a], adds={
        p: ehr(p, diabetes_risk=float(rng.random()), model="v2")
        for p in range(200, 260)})

    # Team B branches from the same baseline: cardiac cohort
    team_b = rs.commit([v_base], adds={
        p: ehr(p, cardiac_flag=bool(rng.random() < 0.2))
        for p in range(0, 150, 3)})

    # merge both teams' results for a combined study
    combined = rs.commit([team_a2, team_b],
                         adds={999: ehr(999, cohort="combined-study")})

    # --- provenance: which EHR version trained model v2? -------------------
    recs, st = rs.get_version(team_a2)
    print(f"model-v2 training snapshot: {len(recs)} EHRs "
          f"({st.chunks_fetched} chunks, {st.kvs_queries} KVS round-trips)")

    # --- cohort query (Q2): patients 200-259 in the combined version -------
    cohort, st = rs.get_range(combined, 200, 259)
    scored = sum(1 for b in cohort.values() if b"diabetes_risk" in b)
    print(f"combined-study cohort [200,259]: {len(cohort)} records, "
          f"{scored} carry risk scores, span={st.chunks_fetched}")

    # --- patient history (Q3): every version of patient 210 ----------------
    evo, st = rs.get_evolution(210)
    print(f"patient 210 history: {len(evo)} versions "
          f"(origins {[v for v, _ in evo]}), span={st.chunks_fetched}")
    for origin, payload in evo:
        d = json.loads(payload)
        print(f"   v{origin}: model={d.get('model', '-')}, "
              f"risk={d.get('diabetes_risk', '-')}")

    # --- storage: dedupe + sub-chunk compression ----------------------------
    print("storage:", rs.storage_stats())


if __name__ == "__main__":
    main()
