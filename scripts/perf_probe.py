"""§Perf profiler: compile one cell and print top FLOP/byte/collective
contributors by jax op_name (dry-run profile — no wall clock on CPU).

  PYTHONPATH=src:. python scripts/perf_probe.py kimi-k2-1t-a32b train_4k [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import argparse

from benchmarks import hlo_analysis as H
from benchmarks import roofline as R
from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import mesh_env


def shorten(name: str, width: int = 110) -> str:
    name = name.replace("jit(train_step)/", "").replace("jit(", "").replace(")", "")
    return name[-width:]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.set:
        import dataclasses
        over = {}
        for kv in args.set:
            k, v = kv.split("=", 1)
            cur = getattr(cfg, k)
            over[k] = type(cur)(v) if not isinstance(cur, bool) \
                else v.lower() in ("1", "true", "yes")
        cfg = dataclasses.replace(cfg, **over)
        print("overrides:", over)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    from repro.sharding.rules import rules_for
    with mesh_env(mesh, rules=rules_for(cfg, mesh)) as env:
        fn, specs = build_cell(cfg, shape, env)
        compiled = fn.lower(*specs).compile()
    hlo = compiled.as_text()
    roof = R.analyze(compiled, cfg, shape.kind, shape.seq_len,
                     shape.global_batch, mesh.devices.size)
    ma = compiled.memory_analysis()
    hbm = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    print(f"== {args.arch} × {args.shape} "
          f"{'2x16x16' if args.multi_pod else '16x16'} ==")
    print(f"t_comp={roof.t_compute:.3f}s t_mem={roof.t_memory:.3f}s "
          f"t_coll={roof.t_collective:.3f}s bound={roof.bottleneck} "
          f"hbm/dev={hbm/2**30:.1f}GiB useful={roof.useful_flops_fraction:.3f} "
          f"roofline={roof.roofline_fraction:.4f}")
    print("\n-- top FLOPs --")
    for name, fl in H.flops_breakdown(hlo, args.top):
        print(f"{fl:.3e}  {shorten(name)}")
    print("\n-- top HBM bytes --")
    for name, b in H.bytes_breakdown(hlo, args.top):
        print(f"{b/2**30:9.2f}G  {shorten(name)}")
    print("\n-- top collective bytes (ring-model) --")
    for name, b in H.collective_breakdown(hlo, args.top):
        print(f"{b/2**30:9.2f}G  {shorten(name)}")


if __name__ == "__main__":
    main()
