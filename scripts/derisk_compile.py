"""De-risk: can we lower+compile a scanned transformer train step on a 512-device
host-platform mesh within acceptable time/memory on 1 CPU core?"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

t0 = time.time()
mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print(f"mesh built in {time.time()-t0:.1f}s, ndev={len(jax.devices())}")

L, D, F, V = 16, 1024, 4096, 32000
B, S = 32, 1024


def init_shapes():
    return {
        "emb": jax.ShapeDtypeStruct((V, D), jnp.bfloat16),
        "wq": jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16),
        "wo": jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16),
        "w1": jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
        "w2": jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16),
    }


def fwd(params, tokens):
    x = params["emb"][tokens]

    def layer(x, p):
        wq, wo, w1, w2 = p
        h = jnp.einsum("bsd,de->bse", x, wq)
        a = jax.nn.softmax(jnp.einsum("bsd,btd->bst", h, h) / 32.0, axis=-1)
        x = x + jnp.einsum("bst,btd->bsd", a, x) @ wo
        x = x + jax.nn.relu(x @ w1) @ w2
        return x, None

    x, _ = jax.lax.scan(layer, x, (params["wq"], params["wo"], params["w1"], params["w2"]))
    return jnp.einsum("bsd,vd->bsv", x, params["emb"])


def loss_fn(params, batch):
    logits = fwd(params, batch["tokens"])
    onehot = jax.nn.one_hot(batch["labels"], V, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits, axis=-1) * onehot, axis=-1))


def train_step(params, batch):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    params = jax.tree.map(lambda p, g: (p.astype(jnp.float32) - 1e-3 * g).astype(p.dtype), params, grads)
    return params, loss


pspecs = {
    "emb": P("model", ("pod", "data")),
    "wq": P(None, ("pod", "data"), "model"),
    "wo": P(None, "model", ("pod", "data")),
    "w1": P(None, ("pod", "data"), "model"),
    "w2": P(None, "model", ("pod", "data")),
}
param_sh = jax.tree.map(lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
                        init_shapes(), pspecs)
batch_sh = {
    "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=NamedSharding(mesh, P(("pod", "data"), None))),
    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=NamedSharding(mesh, P(("pod", "data"), None))),
}

t0 = time.time()
lowered = jax.jit(train_step, donate_argnums=(0,)).lower(param_sh, batch_sh)
print(f"lowered in {time.time()-t0:.1f}s")
t0 = time.time()
compiled = lowered.compile()
print(f"compiled in {time.time()-t0:.1f}s")
ma = compiled.memory_analysis()
print("argument bytes/dev:", ma.argument_size_in_bytes)
print("temp bytes/dev:", ma.temp_size_in_bytes)
ca = compiled.cost_analysis()
print("flops:", ca.get("flops"), "bytes accessed:", ca.get("bytes accessed"))
txt = compiled.as_text()
import re
colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", txt)
from collections import Counter
print("collectives:", Counter(colls))
