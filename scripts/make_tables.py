"""Render EXPERIMENTS.md tables from benchmarks/results/dryrun/*.json."""
import json
import pathlib
import sys

DIR = pathlib.Path("benchmarks/results/dryrun")
ARCH_ORDER = ["mamba2-130m", "internlm2-20b", "smollm-360m", "qwen2.5-32b",
              "stablelm-1.6b", "whisper-base", "jamba-1.5-large-398b",
              "granite-moe-1b-a400m", "kimi-k2-1t-a32b", "internvl2-26b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    recs = {}
    for f in DIR.glob(f"*__{mesh}.json"):
        if "__opt" in f.name and not mesh.endswith("__opt"):
            continue
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(mesh="pod16x16"):
    recs = load(mesh)
    print(f"\n### Roofline — {mesh} (per-chip: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
          "HBM GiB/dev | useful-FLOPs | roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | {r['reason']} | — | — | — |")
                continue
            ro = r["roofline"]
            mem = r["memory"]["per_device_hbm_bytes"]
            print(f"| {a} | {s} | {ro['t_compute_s']:.3g} | "
                  f"{ro['t_memory_s']:.3g} | {ro['t_collective_s']:.3g} | "
                  f"{ro['bottleneck']} | {fmt_bytes(mem)} | "
                  f"{ro['useful_flops_fraction']:.3f} | "
                  f"{ro['roofline_fraction']:.3f} |")


def dryrun_table():
    print("\n### Dry-run matrix (lower+compile status, both meshes)\n")
    single, multi = load("pod16x16"), load("pod2x16x16")
    print("| arch | shape | 16×16 | 2×16×16 | compile s (1pod/2pod) | "
          "collectives (1 pod) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1, r2 = single.get((a, s)), multi.get((a, s))
            if r1 is None and r2 is None:
                continue
            def st(r):
                if r is None:
                    return "…"
                return {"ok": "OK", "skipped": "skip", "error": "FAIL"}[r["status"]]
            cs = f"{r1.get('compile_s','—') if r1 else '—'}/" \
                 f"{r2.get('compile_s','—') if r2 else '—'}"
            colls = ""
            if r1 and r1["status"] == "ok":
                colls = " ".join(f"{k}:{v}" for k, v in
                                 sorted(r1["roofline"]["coll_counts"].items()))
            print(f"| {a} | {s} | {st(r1)} | {st(r2)} | {cs} | {colls} |")


def opt_table():
    base = load("pod16x16")
    opt = load("pod16x16__opt")
    if not opt:
        return
    print("\n### Optimized variants (§Perf winners applied) — pod16x16\n")
    print("| arch | shape | roofline base → opt | t dominant base → opt (s) | "
          "HBM GiB/dev base → opt |")
    print("|---|---|---|---|---|")
    for (a, s), r in sorted(opt.items()):
        b = base.get((a, s))
        if r.get("status") != "ok" or not b or b.get("status") != "ok":
            continue
        ro, rb = r["roofline"], b["roofline"]
        tmax = lambda x: max(x["t_compute_s"], x["t_memory_s"], x["t_collective_s"])
        mo = r["memory"]["per_device_hbm_bytes"] / 2**30
        mb = b["memory"]["per_device_hbm_bytes"] / 2**30
        print(f"| {a} | {s} | {rb['roofline_fraction']:.4f} → "
              f"**{ro['roofline_fraction']:.4f}** | {tmax(rb):.3g} → {tmax(ro):.3g} | "
              f"{mb:.1f} → {mo:.1f} |")


def patch_experiments():
    import io, contextlib
    def cap(fn, *a):
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            fn(*a)
        return buf.getvalue()
    exp = pathlib.Path("EXPERIMENTS.md").read_text()
    exp = exp.replace("<!-- DRYRUN_TABLE -->", cap(dryrun_table))
    exp = exp.replace("<!-- ROOFLINE_TABLE -->",
                      cap(roofline_table) + cap(roofline_table, "pod2x16x16")
                      + cap(opt_table))
    pathlib.Path("EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md tables patched")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table()
    if which in ("all", "roofline"):
        roofline_table()
        roofline_table("pod2x16x16")
    if which in ("all", "opt"):
        opt_table()
    if which == "patch":
        patch_experiments()
