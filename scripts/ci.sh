#!/usr/bin/env bash
# Tier-1 CI: best-effort dev-dep install, then the canonical test command.
# Offline-safe — tests/conftest.py shims hypothesis when it can't install,
# so the non-property tests still collect and run.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "warn: dev-dep install failed (offline?); continuing with shim"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Fast bench smoke on tiny sizes: the write/read-path benchmarks assert
# their round-trip counts (1 multiput per shard for a group flush; 1
# multiget per session), the compaction bench asserts the maintenance
# path's contract (one multiput round trip per touched shard plus one
# multidelete round trip per touched shard per pass, retained versions
# byte-identical), and the fault-tolerance bench asserts the degraded-mode
# contract (replicated R=2 run with one replica killed: reads still succeed
# byte-identically with ≤1 extra round trip per failed-over shard batch,
# and RecoveryManager.rebuild restores each replica in ≤4 round trips),
# and the chunk-cache bench asserts the cache contract ((1) a fully warm
# cache serves the mixed-64 batch with 0 backend read round trips, (2) a
# cold cache costs exactly the seed's round-trip counts — the layer adds
# no traffic, (3) post-compaction reads through a warm cache stay
# byte-identical to fresh uncached reads), and the secondary-index bench
# asserts the filtered-scan contract (a selective Q.where fetches ≤25% of
# the chunks and costs ≥4x fewer simulated seconds than the
# full-version-fetch baseline on the same predicate, results byte-identical
# to the brute-force filter, and warm cached filtered scans run with 0
# backend read round trips), and the async-ingest bench asserts the
# background-flusher contract (8 concurrent sessions staging versions at 0
# backend round trips per commit, one cross-session drain costing ≤1 write
# round trip per shard, ≥3x lower simulated write seconds than per-session
# synchronous flushes, and the same workload on replicated shards with one
# replica of every group killed mid-drain staying byte-identical to a
# synchronous-flush oracle with recover_all converging every replica), and
# the query-planner bench asserts the planner contract (a composite AND of
# two selective predicates runs as ONE and_popcount-family kernel launch
# plus ONE interleaved multiget, fetches fewer chunks than either predicate
# alone, and is byte-identical to the client-side two-session intersection
# it replaces; index-only Q.count/Q.distinct report 0 chunk-payload read
# round trips) — so a round-trip, availability, cache-coherence,
# index-selectivity, ingest-batching, or plan-quality regression fails CI
# here instead of waiting for a full benchmark run.
echo "== bench smoke (round-trip regression gate) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from benchmarks import (bench_async_ingest, bench_batched_query, bench_cache,
                        bench_compaction, bench_fault_tolerance,
                        bench_planner, bench_secondary, bench_write_path)
bench_write_path.run(smoke=True)
bench_async_ingest.run(smoke=True)
bench_batched_query.run(smoke=True)
bench_compaction.run(smoke=True)
bench_fault_tolerance.run(smoke=True)
bench_cache.run(smoke=True)
bench_secondary.run(smoke=True)
bench_planner.run(smoke=True)
print("bench smoke OK")
EOF
