#!/usr/bin/env bash
# Tier-1 CI: best-effort dev-dep install, then the canonical test command.
# Offline-safe — tests/conftest.py shims hypothesis when it can't install,
# so the non-property tests still collect and run.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "warn: dev-dep install failed (offline?); continuing with shim"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
